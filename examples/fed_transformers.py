"""Beyond-paper: FedADP over a heterogeneous TRANSFORMER cohort.

Clients hold depth/width variants of one assigned architecture family
(default: glm4-9b reduced). NetChange aligns them to the union
architecture for aggregation, exactly like the VGG cohort in the paper —
demonstrating the framework's first-class integration of the technique
with modern architectures (DESIGN.md §2).

  PYTHONPATH=src python examples/fed_transformers.py [--arch glm4-9b]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import FedADP, TransformerFamily, tfamily
from repro.data import lm_sequences
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--steps-per-round", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    base = reduced(get_config(args.arch), n_units=2, d_model=256)
    # heterogeneous cohort: full / shallow / narrow / shallow+narrow
    variants = [
        tfamily.make_variant(base, n_units=2, ffn_scale=1.0),
        tfamily.make_variant(base, n_units=1, ffn_scale=1.0),
        tfamily.make_variant(base, n_units=2, ffn_scale=0.5),
        tfamily.make_variant(base, n_units=1, ffn_scale=0.5),
    ][: args.clients]
    family = TransformerFamily()
    algo = FedADP(family, variants, n_samples=[4, 2, 2, 1][: args.clients],
                  narrow_mode="fold")
    print(f"# global architecture: {algo.global_cfg.name} "
          f"L={algo.global_cfg.n_layers} d_ff={algo.global_cfg.d_ff}")

    opt = sgd(0.05)

    def local_train(k, params):
        cfg = variants[k]
        lg = jax.jit(family.loss_and_grad(cfg))
        state = opt.init(params)
        for s in range(args.steps_per_round):
            seqs = lm_sequences(cfg.vocab_size, 4, args.seq,
                                seed=1000 * k + s)
            batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
            (loss, _), grads = lg(params, batch)
            params, state = opt.update(grads, state, params, s)
        return params

    gp = algo.init_global(jax.random.PRNGKey(0))
    eval_seqs = lm_sequences(base.vocab_size, 8, args.seq, seed=777)
    eval_batch = {"tokens": eval_seqs[:, :-1], "labels": eval_seqs[:, 1:]}
    for r in range(args.rounds):
        gp = algo.round(gp, local_train, r)
        losses = [family.evaluate(algo.distribute(gp, r + 1, k), variants[k],
                                  eval_batch)
                  for k in range(len(variants))]
        print(f"round {r+1}: per-client eval loss = "
              + "  ".join(f"{l:.3f}" for l in losses))


if __name__ == "__main__":
    main()
