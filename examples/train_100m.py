"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps on the synthetic Markov corpus and verify the loss drops.

The model is a glm4-9b family member scaled to ~100M params (the same
code path the production launcher uses — launch/train.py — with the full
config swapped in on real hardware).

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_config, reduced
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="artifacts/train_100m.npz")
    args = ap.parse_args()

    # 12 layers x d_model 768 (glm4 family geometry) ~= 100M parameters
    res = run("glm4-9b", use_reduced=True, d_model=768, n_units=6,
              steps=args.steps, batch=args.batch, seq=args.seq, lr=3e-4,
              ckpt=args.ckpt, log_every=20)
    losses = res["losses"]
    l0, l1 = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss: {l0:.3f} -> {l1:.3f}")
    assert l1 < l0 - 0.2, "training did not make progress"
    print("OK: loss improved")


if __name__ == "__main__":
    main()
