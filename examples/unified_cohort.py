"""Cohort-parallel FedADP: the unified engine vs the per-client loop.

A depth-heterogeneous VGG cohort (the setting where the unified-space
embedding is EXACT — DESIGN.md §2) is trained twice with identical data
and SGD+momentum: once through the reference per-client loop, once as a
single stacked vmapped program (fl/engine.py), shard_map-ed over the
client axis when more than one device is available.

  PYTHONPATH=src python examples/unified_cohort.py
"""
import numpy as np

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator
from repro.sharding import cohort_mesh


def main():
    archs = ("vgg13", "vgg15", "vgg17", "vgg19")     # depth-only cohort
    client_cfgs = [scaled(vgg(a), 0.125, 64) for a in archs for _ in range(2)]
    K = len(client_cfgs)
    data = image_classification(EASY, 160 * K, seed=0)
    test = image_classification(EASY, 400, seed=99)
    parts = iid_partition(160 * K, K, seed=0)
    mesh = cohort_mesh(K)                            # None on 1 device
    print(f"{K} clients, client mesh: {mesh}")

    for engine in ("loop", "unified"):
        samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=32,
                                  seed=i) for i, p in enumerate(parts)]
        cfg = FLRunConfig(method="fedadp", rounds=4, local_epochs=1, lr=0.05,
                          momentum=0.9, eval_every=2, engine=engine)
        res = Simulator(VGGFamily(), client_cfgs, samplers, cfg, test,
                        mesh=mesh if engine == "unified" else None).run()
        print(f"{engine:8s} acc by round: "
              + "  ".join(f"{a:.3f}" for a in res["history"])
              + f"   wall {res['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
