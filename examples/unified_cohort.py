"""Cohort-parallel FedADP: the unified backend vs the per-client loop.

A depth+width-heterogeneous VGG cohort (both dimensions are
loop-equivalent in the unified space — segment operators, DESIGN.md §2)
is trained twice with identical data and SGD+momentum through the same
``Federation`` + ``FedADPStrategy``, swapping only the execution
backend: once through the reference per-client ``LoopBackend``, once as
a single stacked vmapped program (``UnifiedBackend`` around
fl/engine.py), shard_map-ed over the client axis when more than one
device is available.

  PYTHONPATH=src python examples/unified_cohort.py
"""
import jax

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import Federation, FedADPStrategy, LoopBackend, UnifiedBackend
from repro.sharding import cohort_mesh


def main(*, rounds=4, local_epochs=1, eval_every=2, width=64,
         archs=("vgg13", "vgg16-wider", "vgg17", "vgg19-wider"),
         per_arch=2, n_per_client=160, n_test=400):
    family = VGGFamily()
    client_cfgs = [scaled(vgg(a), 0.125, width)
                   for a in archs for _ in range(per_arch)]
    K = len(client_cfgs)
    data = image_classification(EASY, n_per_client * K, seed=0)
    test = image_classification(EASY, n_test, seed=99)
    parts = iid_partition(n_per_client * K, K, seed=0)
    mesh = cohort_mesh(K)                            # None on 1 device
    print(f"{K} clients, client mesh: {mesh}")

    results = {}
    for engine in ("loop", "unified"):
        samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=32,
                                  seed=i) for i, p in enumerate(parts)]
        strategy = FedADPStrategy(family, client_cfgs,
                                  [s.n_samples for s in samplers])
        if engine == "unified":
            backend = UnifiedBackend(family, client_cfgs, samplers,
                                     local_epochs=local_epochs, lr=0.05,
                                     momentum=0.9, mesh=mesh)
        else:
            backend = LoopBackend(family, client_cfgs, samplers,
                                  local_epochs=local_epochs, lr=0.05,
                                  momentum=0.9)
        fed = Federation(strategy, backend, rounds=rounds, eval_batch=test,
                         eval_every=eval_every)
        res = fed.run(jax.random.PRNGKey(0))
        print(f"{engine:8s} acc by round: "
              + "  ".join(f"{a:.3f}" for a in res["history"])
              + f"   wall {res['wall_s']:.1f}s")
        results[engine] = res
    return results


if __name__ == "__main__":
    main()
