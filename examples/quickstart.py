"""Quickstart: FedADP through the Federation API in ~40 lines.

Three clients with DIFFERENT VGG architectures jointly train one global
model on synthetic image classification; compare against standalone local
training after a few rounds.

The three moving parts (DESIGN.md §7): a ``Strategy`` (the method's
distribute/collect/aggregate math), a backend (``LoopBackend`` = the
reference per-client execution), and the ``Federation`` orchestrator
(rounds, participation, callbacks, checkpoints).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import Federation, LoopBackend, make_strategy


def main(*, rounds=6, local_epochs=2, eval_every=2, n=1200, n_test=400,
         width=64, archs=("vgg13", "vgg16-wider", "vgg19"), per_arch=2,
         methods=("fedadp", "standalone")):
    # heterogeneous cohort: every client runs a different architecture
    family = VGGFamily()
    client_cfgs = [scaled(vgg(a), 0.125, width)
                   for a in archs for _ in range(per_arch)]
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, n_test, seed=99)
    parts = iid_partition(n, len(client_cfgs), seed=0)

    results = {}
    for method in methods:
        samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=32,
                                  seed=i) for i, p in enumerate(parts)]
        strategy = make_strategy(method, family, client_cfgs,
                                 [s.n_samples for s in samplers])
        backend = LoopBackend(family, client_cfgs, samplers,
                              local_epochs=local_epochs, lr=0.05,
                              momentum=0.9)
        fed = Federation(strategy, backend, rounds=rounds, eval_batch=test,
                         eval_every=eval_every)
        res = fed.run(jax.random.PRNGKey(0))
        print(f"{method:11s} accuracy by round: "
              + "  ".join(f"{a:.3f}" for a in res["history"]))
        results[method] = res
    return results


if __name__ == "__main__":
    main()
