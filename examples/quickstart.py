"""Quickstart: FedADP in ~40 lines.

Three clients with DIFFERENT VGG architectures jointly train one global
model on synthetic image classification; compare against standalone local
training after a few rounds.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator


def main():
    # heterogeneous cohort: every client runs a different architecture
    client_cfgs = [scaled(vgg(a), 0.125, 64)
                   for a in ("vgg13", "vgg16-wider", "vgg19")
                   for _ in range(2)]
    data = image_classification(EASY, 1200, seed=0)
    test = image_classification(EASY, 400, seed=99)
    parts = iid_partition(1200, len(client_cfgs), seed=0)

    for method in ("fedadp", "standalone"):
        samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=32,
                                  seed=i) for i, p in enumerate(parts)]
        cfg = FLRunConfig(method=method, rounds=6, local_epochs=2, lr=0.05,
                          momentum=0.9, eval_every=2)
        res = Simulator(VGGFamily(), client_cfgs, samplers, cfg, test).run()
        print(f"{method:11s} accuracy by round: "
              + "  ".join(f"{a:.3f}" for a in res["history"]))


if __name__ == "__main__":
    main()
