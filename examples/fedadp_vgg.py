"""The paper's experiment, end to end: 20 clients, 8 VGG architectures
(6x VGG-19, 2x each of the others), 4 methods, synthetic Table-1 proxy
datasets (offline gate — see DESIGN.md §2).

  PYTHONPATH=src python examples/fedadp_vgg.py [--rounds 12] [--clients 20]
      [--task synth-easy|synth-medium|synth-hard|synth-hardest]
      [--narrow-mode paper|fold] [--filler zero|global]
"""
import argparse

import numpy as np

from repro.configs.vgg_family import paper_client_archs, scaled, vgg
from repro.core import VGGFamily
from repro.data import (ClientSampler, TABLE1_TASKS, image_classification,
                        iid_partition)
from repro.fl import FLRunConfig, Simulator

TASKS = {t.name: t for t in TABLE1_TASKS}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--train", type=int, default=4000)
    ap.add_argument("--task", default="synth-easy", choices=sorted(TASKS))
    ap.add_argument("--methods", default="fedadp,flexifed,clustered,standalone")
    ap.add_argument("--narrow-mode", default="paper", choices=["paper", "fold"])
    ap.add_argument("--filler", default="zero", choices=["zero", "global"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    archs = paper_client_archs()
    if args.clients < len(archs):
        idx = np.linspace(0, len(archs) - 1, args.clients).round().astype(int)
        archs = tuple(archs[i] for i in idx)
    cfgs = [scaled(vgg(a), 0.125, 64) for a in archs]
    task = TASKS[args.task]
    data = image_classification(task, args.train, seed=args.seed)
    test = image_classification(task, 800, seed=args.seed + 999)
    parts = iid_partition(args.train, len(cfgs), seed=args.seed)

    print(f"# task={task.name} clients={len(cfgs)} rounds={args.rounds}")
    results = {}
    for method in args.methods.split(","):
        samplers = [ClientSampler(data, p, round_fraction=0.2, batch_size=64,
                                  seed=args.seed * 100 + i)
                    for i, p in enumerate(parts)]
        rc = FLRunConfig(method=method, rounds=args.rounds, local_epochs=2,
                         lr=0.03, momentum=0.9, seed=args.seed,
                         narrow_mode=args.narrow_mode, filler=args.filler,
                         eval_every=max(1, args.rounds // 6))
        res = Simulator(VGGFamily(), cfgs, samplers, rc, test).run()
        results[method] = res
        print(f"{method:11s} final={res['final_acc']:.4f} "
              f"history=" + "|".join(f"{a:.3f}" for a in res["history"])
              + f"  ({res['wall_s']:.0f}s)")
    if "fedadp" in results and "flexifed" in results:
        d = results["fedadp"]["final_acc"] - results["flexifed"]["final_acc"]
        print(f"# FedADP - FlexiFed = {d:+.4f} "
              f"(paper: positive, up to +0.233 on CIFAR-100)")


if __name__ == "__main__":
    main()
