"""Benchmark harness — one module per paper table/figure plus the
framework-side reports. Prints ``name,us_per_call,derived`` CSV.

  table1   — paper Table 1 proxy (4 methods x synthetic datasets)
  fig4     — paper Fig. 4 proxy (convergence curves, rounds-to-90%)
  netchange— NetChange transform cost (the method's overhead)
  kernels  — kernel micro-benchmarks + interpret-mode correctness
  roofline — per (arch x shape) roofline terms from the dry-run artifacts

Env: FEDADP_BENCH_FULL=1 for the paper-scale protocol;
     FEDADP_BENCH_ONLY=<name>[,name] to select sections.
"""
from __future__ import annotations

import os
import sys
import time


def main() -> None:
    only = os.environ.get("FEDADP_BENCH_ONLY")
    sections = only.split(",") if only else [
        "kernels", "netchange", "unified", "roofline", "fig4", "table1"]
    csv = ["name,us_per_call,derived"]
    for name in sections:
        t0 = time.time()
        n0 = len(csv)
        try:
            if name == "table1":
                from benchmarks.table1 import main as m
            elif name == "fig4":
                from benchmarks.fig4 import main as m
            elif name == "kernels":
                from benchmarks.kernels import main as m
            elif name == "netchange":
                from benchmarks.netchange_bench import main as m
            elif name == "unified":
                from benchmarks.unified_bench import main as m
            elif name == "roofline":
                from benchmarks.roofline_report import main as m
            elif name == "ablations":
                from benchmarks.ablations import main as m
            else:
                raise KeyError(name)
            csv = m(csv)
            csv.append(f"section/{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # report, keep going
            csv.append(f"section/{name},{(time.time()-t0)*1e6:.0f},"
                       f"ERROR={type(e).__name__}:{str(e)[:80]}")
        print("\n".join(csv[n0:]), file=sys.stderr, flush=True)
    print("\n".join(csv))


if __name__ == "__main__":
    main()
