"""Kernel micro-benchmarks: Pallas (interpret mode, correctness-path) and
the jnp oracle. On-CPU numbers time the REFERENCE path (interpret mode is
a correctness tool, not a perf tool); the derived column reports the
achieved GB/s of the oracle and the kernel's analytic VMEM working set —
the quantity that matters on the TPU target.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main(csv: List[str]):
    key = jax.random.PRNGKey(0)

    # fedavg: K=20 clients x 4M params
    from repro.kernels.fedavg import ref as fref
    K, N = 20, 4_000_000
    x = jax.random.normal(key, (K, N), jnp.float32)
    w = jnp.full((K,), 1.0 / K)
    f = jax.jit(fref.weighted_sum_ref)
    us = _time(f, x, w)
    gbs = K * N * 4 / (us / 1e6) / 1e9
    csv.append(f"kernel/fedavg_ref_{K}x{N},{us:.0f},GBps={gbs:.1f}")
    csv.append(f"kernel/fedavg_vmem_block,0,bytes={K*4096*4}")

    # netchange widen: 4096 rows, 14336 -> 21504 cols
    from repro.core.netchange import dup_mapping
    from repro.kernels.netchange import ref as nref
    R, old, new = 4096, 14336 // 8, 21504 // 8
    xw = jax.random.normal(key, (R, old))  # fedlint: ignore[FDL001] timing-only data; values irrelevant
    m = jnp.asarray(dup_mapping(old, new, tag="b"))
    sc = jnp.ones((new,), jnp.float32)
    g = jax.jit(nref.widen_ref)
    us = _time(g, xw, m, sc)
    csv.append(f"kernel/netchange_widen_ref_{R}x{old}to{new},{us:.0f},"
               f"GBps={(R*(old+new)*4)/(us/1e6)/1e9:.1f}")

    # swa decode: the long-context serving shape (scaled)
    from repro.kernels.swa_attention import ref as sref
    B, KV, G, hd, S = 1, 8, 2, 128, 16384
    q = jax.random.normal(key, (B, KV, G, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    kp = jnp.arange(S)
    h = jax.jit(lambda *a: sref.decode_ref(*a, window=1024))
    us = _time(h, q, kk, vv, kp, jnp.int32(S - 1))
    cache_gb = 2 * B * S * KV * hd * 4 / 1e9
    csv.append(f"kernel/swa_decode_ref_S{S},{us:.0f},"
               f"cache_GBps={cache_gb/(us/1e6):.1f}")

    # Pallas interpret-mode correctness spot checks (tiny, not perf)
    from repro.kernels.fedavg import ops as fops
    from repro.kernels.swa_attention import ops as sops
    xs = jax.random.normal(key, (4, 2048))
    err = float(jnp.abs(fops.weighted_sum(xs, jnp.full((4,), 0.25))
                        - fref.weighted_sum_ref(xs, jnp.full((4,), 0.25))).max())
    csv.append(f"kernel/fedavg_pallas_interpret_err,0,max_abs={err:.2e}")
    S2 = 512
    k2 = jax.random.normal(key, (1, S2, 2, 64))
    q2 = jax.random.normal(key, (1, 4, 64))
    got = sops.decode_attention(q2, k2, k2, jnp.arange(S2), jnp.int32(400),
                                window=128)
    want = sref.decode_ref(q2.reshape(1, 2, 2, 64), k2, k2, jnp.arange(S2),
                           jnp.int32(400), window=128).reshape(1, 4, 64)
    csv.append(f"kernel/swa_pallas_interpret_err,0,"
               f"max_abs={float(jnp.abs(got-want).max()):.2e}")
    return csv
