"""NetChange transform cost: the per-round overhead FedADP adds on the
server (down) and per client (up) — Section III's efficiency story."""
from __future__ import annotations

import time
from typing import List

import jax

from repro.configs.vgg_family import scaled, union_config, vgg, PAPER_COHORT
from repro.core import vggops
from repro.models import vgg as V


def main(csv: List[str]):
    key = jax.random.PRNGKey(0)
    cohort = {a: scaled(vgg(a), 0.25, 256) for a in PAPER_COHORT}
    gcfg = union_config(list(cohort.values()))
    gp = V.init_params(key, gcfg)
    for arch in ("vgg13", "vgg16-wider", "vgg19"):
        cfg = cohort[arch]
        t0 = time.perf_counter()
        cp = vggops.down(gp, gcfg, cfg, mode="paper")
        jax.block_until_ready(jax.tree.leaves(cp)[0])
        t_down = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        up = vggops.up(cp, cfg, gcfg)
        jax.block_until_ready(jax.tree.leaves(up)[0])
        t_up = (time.perf_counter() - t0) * 1e6
        n = sum(l.size for l in jax.tree.leaves(cp))
        csv.append(f"netchange/down/{arch},{t_down:.0f},params={n}")
        csv.append(f"netchange/up/{arch},{t_up:.0f},params={n}")
    return csv
