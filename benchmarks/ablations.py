"""FedADP ablations (beyond-paper, DESIGN.md §2):

  * narrow_mode: paper Alg. 3 (lossy mass redistribution) vs the
    function-preserving fold inverse of Alg. 2,
  * filler: zero (paper — uncovered regions pull the average toward the
    identity filler) vs global (FedADP-U — the server keeps its values).

Run via FEDADP_BENCH_ONLY=ablations; included in the default set only
when FEDADP_BENCH_FULL=1 (it repeats the table1 protocol 4x).
"""
from __future__ import annotations

import os
from typing import List

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, MEDIUM, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator

VARIANTS = (
    ("paper-zero", "paper", "zero"),        # the paper's FedADP
    ("fold-zero", "fold", "zero"),
    ("paper-global", "paper", "global"),    # FedADP-U
    ("fold-global", "fold", "global"),
)


def main(csv: List[str]):
    full = os.environ.get("FEDADP_BENCH_FULL") == "1"
    rounds = 16 if full else 6
    n = 2400 if full else 1200
    archs = ["vgg13", "vgg15", "vgg16-wider", "vgg19"] * 2
    cfgs = [scaled(vgg(a), 0.125, 64) for a in archs]
    task = MEDIUM
    data = image_classification(task, n, seed=3)
    test = image_classification(task, 500, seed=777)
    parts = iid_partition(n, len(cfgs), seed=3)
    for name, narrow, filler in VARIANTS:
        samplers = [ClientSampler(data, p, round_fraction=0.3, batch_size=32,
                                  seed=i) for i, p in enumerate(parts)]
        rc = FLRunConfig(method="fedadp", rounds=rounds, local_epochs=1,
                         lr=0.05, momentum=0.9, narrow_mode=narrow,
                         filler=filler, eval_every=max(1, rounds // 3))
        res = Simulator(VGGFamily(), cfgs, samplers, rc, test).run()
        csv.append(f"ablation/fedadp/{name},{res['wall_s']*1e6:.0f},"
                   f"acc={res['final_acc']:.4f}|hist="
                   + "|".join(f"{a:.3f}" for a in res["history"]))
    return csv
