"""Table 1 proxy: FedADP vs FlexiFed vs Clustered-FL vs Standalone.

The paper's Table 1 reports final accuracy on MNIST / F-MNIST / CIFAR-10 /
CIFAR-100. Offline gate (repro band 2/5): those datasets are not
downloadable here, so the harness runs the same 4-method protocol on the
synthetic proxies (repro.data.synthetic.TABLE1_TASKS) with the paper's
8-architecture VGG cohort at reduced width, and validates the paper's
QUALITATIVE claims: FedADP > FlexiFed > Clustered-FL > Standalone.

Scaled-down default (CI-sized); FEDADP_BENCH_FULL=1 runs closer to the
paper protocol (20 clients, more rounds).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.vgg_family import paper_client_archs, scaled, vgg
from repro.core import VGGFamily
from repro.data import (ClientSampler, TABLE1_TASKS, image_classification,
                        iid_partition)
from repro.fl import (Federation, LoopBackend, UnifiedBackend, make_strategy,
                      unified_eligible)

METHODS = ("fedadp", "flexifed", "clustered", "standalone")


def cohort(n_clients: int):
    archs = paper_client_archs()
    if n_clients < len(archs):
        # keep the architecture mix: sample evenly
        idx = np.linspace(0, len(archs) - 1, n_clients).round().astype(int)
        archs = tuple(archs[i] for i in idx)
    return [scaled(vgg(a), 0.125, 64) for a in archs]


def run_task(task, *, n_clients: int, rounds: int, n_train: int,
             local_epochs: int, seed: int = 0) -> Dict[str, Dict]:
    cfgs = cohort(n_clients)
    data = image_classification(task, n_train, seed=seed)
    test = image_classification(task, max(200, n_train // 5), seed=seed + 999)
    parts = iid_partition(n_train, len(cfgs), seed=seed)
    out: Dict[str, Dict] = {}
    family = VGGFamily()
    for method in METHODS:
        samplers = [ClientSampler(data, p, round_fraction=0.2, batch_size=64,
                                  seed=100 * seed + i)
                    for i, p in enumerate(parts)]
        strategy = make_strategy(method, family, cfgs,
                                 [s.n_samples for s in samplers],
                                 base_seed=seed)
        backend_cls = (UnifiedBackend if unified_eligible(
            strategy, family, cfgs, samplers) else LoopBackend)
        kw = {"seed": seed} if backend_cls is UnifiedBackend else {}
        backend = backend_cls(family, cfgs, samplers,
                              local_epochs=local_epochs, lr=0.03,
                              momentum=0.9, **kw)
        fed = Federation(strategy, backend, rounds=rounds, eval_batch=test,
                         eval_every=max(1, rounds // 6))
        res = fed.run(jax.random.PRNGKey(seed))
        out[method] = {"final": res["final_acc"], "history": res["history"],
                       "wall_s": res["wall_s"]}
    return out


def main(csv: List[str]):
    full = os.environ.get("FEDADP_BENCH_FULL") == "1"
    kw = (dict(n_clients=20, rounds=30, n_train=4000, local_epochs=2) if full
          else dict(n_clients=8, rounds=6, n_train=1200, local_epochs=1))
    tasks = TABLE1_TASKS if full else TABLE1_TASKS[:2]
    for task in tasks:
        t0 = time.time()
        res = run_task(task, **kw)
        dt = time.time() - t0
        accs = {m: res[m]["final"] for m in METHODS}
        order_ok = (accs["fedadp"] >= accs["clustered"]
                    and accs["fedadp"] >= accs["standalone"])
        for m in METHODS:
            csv.append(f"table1/{task.name}/{m},"
                       f"{res[m]['wall_s'] * 1e6 / max(kw['rounds'],1):.0f},"
                       f"acc={accs[m]:.4f}")
        csv.append(f"table1/{task.name}/ordering,{dt*1e6:.0f},"
                   f"fedadp_beats_locals={order_ok}")
    return csv
