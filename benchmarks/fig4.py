"""Fig. 4 proxy: convergence curves of the four methods.

The paper's Fig. 4 claim: FedADP and FlexiFed converge at similar speed,
both far faster than Clustered-FL / Standalone. We measure rounds-to-
threshold on the synthetic easy task and report the curves.
"""
from __future__ import annotations

import os
from typing import List

import numpy as np

from benchmarks.table1 import run_task
from repro.data import EASY


def rounds_to(history, frac_of_best):
    best = max(h for m in history.values() for h in m)
    thr = frac_of_best * best
    out = {}
    for m, h in history.items():
        hit = next((i for i, a in enumerate(h) if a >= thr), None)
        out[m] = hit if hit is not None else len(h)
    return out


def main(csv: List[str]):
    full = os.environ.get("FEDADP_BENCH_FULL") == "1"
    kw = (dict(n_clients=12, rounds=24, n_train=3000, local_epochs=2) if full
          else dict(n_clients=6, rounds=8, n_train=1000, local_epochs=1))
    res = run_task(EASY, seed=1, **kw)
    hist = {m: res[m]["history"] for m in res}
    r90 = rounds_to(hist, 0.9)
    for m, h in hist.items():
        csv.append(f"fig4/curve/{m},0,history=" +
                   "|".join(f"{a:.3f}" for a in h))
    for m, r in r90.items():
        csv.append(f"fig4/rounds_to_90pct/{m},0,rounds={r}")
    return csv
