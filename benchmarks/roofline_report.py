"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun_single.json (written by launch/dryrun.py --all
--json ...) and emits one CSV row per (arch x shape): the three terms,
the dominant bottleneck, and the useful-compute ratio. If artifacts are
missing this bench reports SKIP rows (the dry-run is a separate, heavier
pass — see EXPERIMENTS.md §Dry-run for how it was produced).
"""
from __future__ import annotations

import json
import os
from typing import List

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "dryrun_single.json")


def main(csv: List[str]):
    if not os.path.exists(ART):
        csv.append("roofline/artifacts,0,SKIP=run launch.dryrun --all --json")
        return csv
    with open(ART) as f:
        results = json.load(f)
    for r in results:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] != "OK":
            csv.append(f"{name},0,{r['status']}={r.get('reason', r.get('error', ''))[:60]}")
            continue
        t = r["roofline"]
        csv.append(
            f"{name},{t['step_s_lower_bound']*1e6:.0f},"
            f"dominant={t['dominant']}|compute_s={t['compute_s']:.3e}"
            f"|memory_s={t['memory_s']:.3e}"
            f"|collective_s={t['collective_s']:.3e}"
            f"|useful={t['useful_ratio']:.3f}")
    return csv
