"""Per-client loop vs cohort-parallel unified engine wall clock.

The unified engine (fl/engine.py) replaces the Python loop over K clients
with one stacked vmapped program; this bench measures the per-round wall
clock of both Simulator paths across cohort sizes K in {4, 8, 16} on a
depth-heterogeneous VGG cohort (where the two are numerically equivalent
— tests/test_unified.py). Compile time is excluded by a 1-round warmup
run on the SAME Simulator (grad fns and the engine's jitted step are
cached per instance) before the timed rounds. Numbers feed
EXPERIMENTS.md §Perf.

On a single device the two paths are roughly wall-clock neutral on CPU
(the engine trades K dispatches for union-depth padding FLOPs); the win
is sharding the client axis. FEDADP_BENCH_DEVICES=N forces an N-device
host platform (set BEFORE jax initializes — works standalone or with
FEDADP_BENCH_ONLY=unified) and runs the unified path shard_map-ed over
a client mesh.

CSV rows: unified/K{K}/{loop|unified},us_per_round,rounds=N
"""
from __future__ import annotations

import dataclasses
import os
import sys

_DEV = os.environ.get("FEDADP_BENCH_DEVICES")
if _DEV and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_DEV} "
                               + os.environ.get("XLA_FLAGS", ""))

from typing import List

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator
from repro.sharding import cohort_mesh

DEPTH_ARCHS = ("vgg13", "vgg15", "vgg17", "vgg19")  # depth-only cohort


def _cohort(K: int, n_per_client: int, batch: int):
    family = VGGFamily()
    cfgs = [scaled(vgg(DEPTH_ARCHS[k % len(DEPTH_ARCHS)]), 0.125, 64)
            for k in range(K)]
    n = n_per_client * K
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, 64, seed=99)
    parts = iid_partition(n, K, seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=batch,
                              seed=i) for i, p in enumerate(parts)]

    return family, cfgs, samplers, test


def _per_round(family, cfgs, samplers, test, engine: str, rounds: int) -> float:
    rc = FLRunConfig(method="fedadp", rounds=1, local_epochs=1, lr=0.05,
                     momentum=0.9, eval_every=10 ** 9, engine=engine)
    mesh = cohort_mesh(len(cfgs)) if engine == "unified" else None
    sim = Simulator(family, cfgs, samplers(), rc, test, mesh=mesh)
    sim.run()                                   # warmup: pays compilation
    sim.cfg = dataclasses.replace(rc, rounds=rounds)
    return sim.run()["wall_s"] / rounds


def main(csv: List[str]):
    import jax
    if _DEV and len(jax.devices()) != int(_DEV):
        # jax was initialized before this module could set XLA_FLAGS
        # (e.g. an earlier benchmarks/run.py section imported it) —
        # flag it so single-device rows aren't mistaken for sharded ones.
        csv.append(f"unified/devices,0,WARN=requested {_DEV} devices but "
                   f"jax has {len(jax.devices())}; run standalone or with "
                   "FEDADP_BENCH_ONLY=unified")
    full = os.environ.get("FEDADP_BENCH_FULL")
    n_per_client, batch, rounds = (256, 64, 5) if full else (64, 32, 3)
    for K in (4, 8, 16):
        family, cfgs, samplers, test = _cohort(K, n_per_client, batch)
        per = {}
        for engine in ("loop", "unified"):
            per[engine] = _per_round(family, cfgs, samplers, test, engine,
                                     rounds)
            csv.append(f"unified/K{K}/{engine},{per[engine] * 1e6:.0f},"
                       f"rounds={rounds}")
        csv.append(f"unified/K{K}/speedup,"
                   f"{per['loop'] / max(per['unified'], 1e-9):.2f},x")
    return csv


if __name__ == "__main__":
    rows = main(["name,us_per_call,derived"])
    print("\n".join(rows))
