"""Per-client loop vs cohort-parallel unified engine wall clock, per
aggregation mode and cohort kind.

The unified engine (fl/engine.py) replaces the Python loop over K clients
with one stacked vmapped program; this bench measures the per-round wall
clock of both Simulator paths across cohort sizes K, both aggregation
modes (``filler`` — paper Eq. 1 — and ``coverage`` — the HeteroFL-style
renormalized average from core/aggregation.py) and both cohort kinds —
``depth`` (depth-only heterogeneity) and ``width`` (depth AND width mixed
via the paper's -Wider variants; ISSUE 4: segment-projected training +
per-round embed seeds) — where the two engines are numerically
equivalent (tests/test_unified.py, tests/test_federation.py). Compile
time is excluded by a 1-round warmup run on the SAME Simulator (grad fns
and the engine's jitted steps are cached per instance) before the timed
rounds. Numbers feed EXPERIMENTS.md §Perf.

On a single device the two paths are roughly wall-clock neutral on CPU
(the engine trades K dispatches for union-depth padding FLOPs); the win
is sharding the client axis. FEDADP_BENCH_DEVICES=N forces an N-device
host platform (set BEFORE jax initializes — works standalone or with
FEDADP_BENCH_ONLY=unified) and runs the unified path shard_map-ed over
a client mesh.

An ``agg_layout`` microbench (ISSUE 5) times the aggregation pass ALONE
— ``fedavg_stacked`` on the union cohort with coverage masks + fallback
— in both layouts: ``leaf`` (the per-leaf reference dispatch, one kernel
launch per union leaf) vs ``plane`` (the packed ``core.plane`` path, the
whole model in ONE fused kernel pass). Rows carry the ``agg_layout``
column and a ``dispatches`` count; the engine rows are tagged with the
layout their round actually runs (``plane`` for unified since ISSUE 5,
``tree`` for the loop).

Outputs:
  * CSV rows ``unified/K{K}/{loop|unified}/{agg_mode},us_per_round,...``
    plus per-(K, agg_mode) speedups, and
    ``unified/agg/K{K}/{leaf|plane}/{agg_mode},us_per_call,...`` for the
    aggregation-layout microbench,
  * a machine-readable ``BENCH_unified.json`` (path override:
    FEDADP_BENCH_JSON) so the perf trajectory is diffable across PRs.

Env: FEDADP_BENCH_FULL=1 paper-scale protocol; FEDADP_BENCH_SMOKE=1
tiny-K single-round run for CI (seconds, not minutes).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

_DEV = os.environ.get("FEDADP_BENCH_DEVICES")
if _DEV and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_DEV} "
                               + os.environ.get("XLA_FLAGS", ""))

from typing import List

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator
from repro.sharding import cohort_mesh

DEPTH_ARCHS = ("vgg13", "vgg15", "vgg17", "vgg19")  # depth-only cohort
# depth AND width mixed: the -wider variants widen stage 4's first conv,
# a layer every depth variant owns, so the cohort stays
# segment-representable (family.segment_representable)
WIDTH_ARCHS = ("vgg13", "vgg16-wider", "vgg17", "vgg19-wider")
COHORTS = {"depth": DEPTH_ARCHS, "width": WIDTH_ARCHS}
AGG_MODES = ("filler", "coverage")


def _cohort(K: int, n_per_client: int, batch: int, archs=DEPTH_ARCHS):
    family = VGGFamily()
    cfgs = [scaled(vgg(archs[k % len(archs)]), 0.125, 64)
            for k in range(K)]
    n = n_per_client * K
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, 64, seed=99)
    parts = iid_partition(n, K, seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=batch,
                              seed=i) for i, p in enumerate(parts)]

    return family, cfgs, samplers, test


def _per_round(family, cfgs, samplers, test, engine: str, rounds: int
               ) -> dict:
    """{agg_mode: seconds-per-round}; one Simulator per engine so grad fns
    / engine steps stay warm across the agg_mode sweep."""
    base = FLRunConfig(method="fedadp", rounds=1, local_epochs=1, lr=0.05,
                       momentum=0.9, eval_every=10 ** 9, engine=engine)
    mesh = cohort_mesh(len(cfgs)) if engine == "unified" else None
    sim = Simulator(family, cfgs, samplers(), base, test, mesh=mesh)
    out = {}
    for agg_mode in AGG_MODES:
        sim.cfg = dataclasses.replace(base, agg_mode=agg_mode)
        sim.samplers = samplers()
        sim.run()                               # warmup: pays compilation
        sim.cfg = dataclasses.replace(sim.cfg, rounds=rounds)
        sim.samplers = samplers()
        out[agg_mode] = sim.run()["wall_s"] / rounds
    return out


def _agg_microbench(csv: List[str], records: List[dict], Ks, reps: int):
    """Aggregation-dominated rounds, both layouts: per-leaf dispatch vs
    the packed plane pass, on the union cohort's coverage average (masks
    + fallback — the heaviest variant both layouts fuse)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.aggregation import (fedavg_stacked, global_shapes,
                                        stack_trees, subset_weights)
    from repro.fl.engine import UnifiedEngine

    for K in Ks:
        cfgs = [scaled(vgg(DEPTH_ARCHS[k % len(DEPTH_ARCHS)]), 0.125, 64)
                for k in range(K)]
        eng = UnifiedEngine(VGGFamily(), cfgs, [1] * K, method="fedadp",
                            agg_mode="coverage")
        shapes = global_shapes(eng.family, eng.global_cfg)
        n_leaves = len(jax.tree.leaves(shapes))
        key = jax.random.PRNGKey(0)

        def rand(i):
            leaves, td = jax.tree.flatten(shapes)
            return jax.tree.unflatten(td, [
                jax.random.normal(jax.random.fold_in(key, 97 * i + j),
                                  s.shape).astype(s.dtype)
                for j, s in enumerate(leaves)])

        stacked = stack_trees([rand(i) for i in range(K)])
        fallback = rand(K)
        w = subset_weights([1] * K)
        for agg_mode in AGG_MODES:
            kw = ({} if agg_mode == "filler"
                  else dict(masks=eng.cov_masks, fallback=fallback))
            per = {}
            for layout in ("leaf", "plane"):
                out = fedavg_stacked(stacked, w, layout=layout, **kw)
                jax.block_until_ready(out)          # pay compilation
                t0 = time.perf_counter()
                for _ in range(reps):
                    out = fedavg_stacked(stacked, w, layout=layout, **kw)
                jax.block_until_ready(out)
                sec = (time.perf_counter() - t0) / reps
                per[layout] = sec
                dispatches = 1 if layout == "plane" else n_leaves
                csv.append(f"unified/agg/K{K}/{layout}/{agg_mode},"
                           f"{sec * 1e6:.0f},reps={reps}")
                records.append({"cohort": "agg", "K": K, "engine": "agg",
                                "agg_mode": agg_mode, "agg_layout": layout,
                                "us_per_call": round(sec * 1e6),
                                "dispatches": dispatches, "reps": reps})
            csv.append(
                f"unified/agg/K{K}/speedup/{agg_mode},"
                f"{per['leaf'] / max(per['plane'], 1e-9):.2f},x")


def main(csv: List[str]):
    import jax
    if _DEV and len(jax.devices()) != int(_DEV):
        # jax was initialized before this module could set XLA_FLAGS
        # (e.g. an earlier benchmarks/run.py section imported it) —
        # flag it so single-device rows aren't mistaken for sharded ones.
        csv.append(f"unified/devices,0,WARN=requested {_DEV} devices but "
                   f"jax has {len(jax.devices())}; run standalone or with "
                   "FEDADP_BENCH_ONLY=unified")
    smoke = os.environ.get("FEDADP_BENCH_SMOKE")
    full = os.environ.get("FEDADP_BENCH_FULL")
    if smoke:
        Ks, (n_per_client, batch, rounds) = (2,), (32, 16, 1)
        agg_Ks, agg_reps = (2,), 5
    elif full:
        Ks, (n_per_client, batch, rounds) = (4, 8, 16), (256, 64, 5)
        agg_Ks, agg_reps = (4, 8), 50
    else:
        Ks, (n_per_client, batch, rounds) = (4, 8, 16), (64, 32, 3)
        agg_Ks, agg_reps = (4, 8), 30
    records = []
    for cohort, archs in COHORTS.items():
        prefix = "unified" if cohort == "depth" else f"unified/{cohort}"
        for K in Ks:
            family, cfgs, samplers, test = _cohort(K, n_per_client, batch,
                                                   archs)
            per = {}
            for engine in ("loop", "unified"):
                per[engine] = _per_round(family, cfgs, samplers, test,
                                         engine, rounds)
                for agg_mode, sec in per[engine].items():
                    csv.append(f"{prefix}/K{K}/{engine}/{agg_mode},"
                               f"{sec * 1e6:.0f},rounds={rounds}")
                    records.append({"cohort": cohort, "K": K,
                                    "engine": engine, "agg_mode": agg_mode,
                                    "agg_layout": ("plane"
                                                   if engine == "unified"
                                                   else "tree"),
                                    "us_per_round": round(sec * 1e6),
                                    "rounds": rounds})
            for agg_mode in AGG_MODES:
                csv.append(
                    f"{prefix}/K{K}/speedup/{agg_mode},"
                    f"{per['loop'][agg_mode] / max(per['unified'][agg_mode], 1e-9):.2f},x")
    _agg_microbench(csv, records, agg_Ks, agg_reps)
    path = os.environ.get("FEDADP_BENCH_JSON", "BENCH_unified.json")
    with open(path, "w") as f:
        json.dump({"bench": "unified_bench",
                   "protocol": {"rounds": rounds,
                                "n_per_client": n_per_client,
                                "batch": batch, "local_epochs": 1,
                                "smoke": bool(smoke), "full": bool(full),
                                "devices": len(jax.devices()),
                                "backend": jax.default_backend()},
                   "rows": records}, f, indent=1)
    csv.append(f"unified/json,0,{path}")
    return csv


if __name__ == "__main__":
    rows = main(["name,us_per_call,derived"])
    print("\n".join(rows))
