"""Per-client loop vs cohort-parallel unified engine wall clock, per
aggregation mode and cohort kind.

The unified engine (fl/engine.py) replaces the Python loop over K clients
with one stacked vmapped program; this bench measures the per-round wall
clock of both Simulator paths across cohort sizes K, both aggregation
modes (``filler`` — paper Eq. 1 — and ``coverage`` — the HeteroFL-style
renormalized average from core/aggregation.py) and both cohort kinds —
``depth`` (depth-only heterogeneity) and ``width`` (depth AND width mixed
via the paper's -Wider variants; ISSUE 4: segment-projected training +
per-round embed seeds) — where the two engines are numerically
equivalent (tests/test_unified.py, tests/test_federation.py). Compile
time is excluded by a 1-round warmup run on the SAME Simulator (grad fns
and the engine's jitted steps are cached per instance) before the timed
rounds. Numbers feed EXPERIMENTS.md §Perf.

On a single device the two paths are roughly wall-clock neutral on CPU
(the engine trades K dispatches for union-depth padding FLOPs); the win
is sharding the client axis. FEDADP_BENCH_DEVICES=N forces an N-device
host platform (set BEFORE jax initializes — works standalone or with
FEDADP_BENCH_ONLY=unified) and runs the unified path shard_map-ed over
a client mesh.

An ``agg_layout`` microbench (ISSUE 5, extended by ISSUE 8) times the
aggregation pass ALONE — ``fedavg_stacked`` on the union cohort with
coverage masks + fallback — in all three layouts: ``leaf`` (the
per-leaf reference dispatch, one kernel launch per union leaf) vs
``plane`` (the packed ``core.plane`` path, the whole model in ONE
fused kernel pass) vs ``stream`` (the O(P·k_chunk) chunked
``PlaneAccumulator`` path that scales the client axis past what a
resident ``(K, P)`` plane allows). The microbench sweeps the SCALE Ks
(64, 128 by default — training rounds there would be
wall-clock-prohibitive on CI, the aggregation pass is the part that
scales) and every row carries a ``peak_agg_bytes`` column
(``core.aggregation.last_agg_stats``) so the O(K·P) → O(P) memory drop
is diffable, not just the wall clock. Engine rows are tagged with the
layout their round actually ran (``engine.agg_stats()`` — "plane",
"stream" or "edge"; ``tree`` for the loop) plus the same peak-bytes
column.

A ``tffn`` training sweep (ISSUE 10) times TRANSFORMER unified rounds —
the width-heterogeneous reduced-glm4 cohort — across the attention
backend (``blockwise`` XLA vs ``flash``: Pallas kernels on TPU, the
vectorised jnp flash elsewhere) and the local-training compute dtype
(``f32`` vs ``bf16`` mixed precision). Every unified training row now
carries a ``us_train``/``us_agg`` split (``engine.phase_stats()``
wall-clocks the donated training steps; the remainder is round start +
embedding + aggregation) so attention/precision wins — which only touch
the training phase — are attributable, not diluted into the round total.

A ``wire`` microbench (ISSUE 9) times the COMPRESSED aggregation pass —
client-side error-feedback encode (``core.quant``) + the fused
dequantize-accumulate streaming kernel — for every wire format
(f32 / bf16 / int8 / int8+sparse) on the width cohort's coverage
average, and emits ``bytes_per_round`` (the client->server payload) and
``reduction`` columns next to the wall clock: the wire is a
bytes-on-the-network optimization first.

Outputs:
  * CSV rows ``unified/K{K}/{loop|unified}/{agg_mode},us_per_round,...``
    plus per-(K, agg_mode) speedups,
    ``unified/agg/K{K}/{leaf|plane|stream}/{agg_mode},us_per_call,...``
    for the aggregation-layout microbench, and
    ``unified/wire/K{K}/{wire},us_per_call,bytes_per_round=...`` for
    the wire-format microbench,
  * a machine-readable ``BENCH_unified.json`` (path override:
    FEDADP_BENCH_JSON) so the perf trajectory is diffable across PRs.

Env: FEDADP_BENCH_FULL=1 paper-scale protocol; FEDADP_BENCH_SMOKE=1
tiny-K single-round run for CI (seconds, not minutes — still includes
one K=64 streaming row). ``--K 4,8,64`` (comma list, validated before
any work runs) overrides both sweeps' cohort sizes.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

_DEV = os.environ.get("FEDADP_BENCH_DEVICES")
if _DEV and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_DEV} "
                               + os.environ.get("XLA_FLAGS", ""))

from typing import List

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator
from repro.sharding import cohort_mesh

DEPTH_ARCHS = ("vgg13", "vgg15", "vgg17", "vgg19")  # depth-only cohort
# depth AND width mixed: the -wider variants widen stage 4's first conv,
# a layer every depth variant owns, so the cohort stays
# segment-representable (family.segment_representable)
WIDTH_ARCHS = ("vgg13", "vgg16-wider", "vgg17", "vgg19-wider")
COHORTS = {"depth": DEPTH_ARCHS, "width": WIDTH_ARCHS}
AGG_MODES = ("filler", "coverage")


def _cohort(K: int, n_per_client: int, batch: int, archs=DEPTH_ARCHS):
    family = VGGFamily()
    cfgs = [scaled(vgg(archs[k % len(archs)]), 0.125, 64)
            for k in range(K)]
    n = n_per_client * K
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, 64, seed=99)
    parts = iid_partition(n, K, seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=batch,
                              seed=i) for i, p in enumerate(parts)]

    return family, cfgs, samplers, test


def _per_round(family, cfgs, samplers, test, engine: str, rounds: int,
               base: FLRunConfig = None, agg_modes=AGG_MODES) -> dict:
    """{agg_mode: (seconds-per-round, engine agg stats | None, train
    seconds-per-round | None)}; one Simulator per engine so grad fns /
    engine steps stay warm across the agg_mode sweep. The unified stats
    come from ``engine.agg_stats()`` — the layout the round ACTUALLY ran
    plus its peak aggregation footprint (DESIGN.md §9) — and the train
    split from ``engine.phase_stats()`` (``timing=True`` syncs after the
    local-training steps; ``us_agg`` = round minus train, i.e. round
    start + embedding + aggregation)."""
    if base is None:
        base = FLRunConfig(method="fedadp", rounds=1, local_epochs=1,
                           lr=0.05, momentum=0.9, eval_every=10 ** 9,
                           engine=engine)
    mesh = cohort_mesh(len(cfgs)) if engine == "unified" else None
    sim = Simulator(family, cfgs, samplers(), base, test, mesh=mesh)
    out = {}
    for agg_mode in agg_modes:
        sim.cfg = dataclasses.replace(base, agg_mode=agg_mode)
        sim.samplers = samplers()
        sim.run()                               # warmup: pays compilation
        be = None
        if engine == "unified":
            be = next(b for k, b in sim._backends.items()
                      if k[0] == "unified")
            be.engine.timing = True
            be.engine.phase_stats(reset=True)
        sim.cfg = dataclasses.replace(sim.cfg, rounds=rounds)
        sim.samplers = samplers()
        sec = sim.run()["wall_s"] / rounds
        stats = train_s = None
        if be is not None:
            stats = be.engine.agg_stats()
            train_s = be.engine.phase_stats(reset=True)["train"] / rounds
        out[agg_mode] = (sec, stats, train_s)
    return out


# transformer training rounds: the flash-attention backend and the bf16
# compute policy (ISSUE 10) on the tffn cohort — reduced glm4-9b with
# full-width and half-FFN variants, the width-heterogeneous transformer
# analogue of the VGG -wider sweep
TFFN_ATTN = ("blockwise", "flash")
TFFN_DTYPES = ("f32", "bf16")


def _tffn_cohort(K: int, S: int = 64, batch: int = 8,
                 n_per_client: int = 16):
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core import TransformerFamily, tfamily

    base = reduced(get_config("glm4-9b"), n_units=2, d_model=64)
    cfgs = [tfamily.make_variant(base, ffn_scale=0.5) if k % 2
            else tfamily.make_variant(base) for k in range(K)]
    family = TransformerFamily()
    n = n_per_client * K
    rng = np.random.default_rng(0)
    toks = rng.integers(0, base.vocab_size, size=(n, S + 1)).astype(np.int32)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    test = {"tokens": toks[:16, :-1], "labels": toks[:16, 1:]}
    parts = iid_partition(n, K, seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=batch,
                              seed=i) for i, p in enumerate(parts)]

    return family, cfgs, samplers, test


def _tffn_bench(csv: List[str], records: List[dict], Ks, rounds: int):
    """Unified training rounds on the tffn cohort, attention backend x
    compute dtype. ``us_train``/``us_agg`` split every row: the flash
    path only touches the local-training step, so the split shows WHERE
    the win lands. Off-TPU the "flash" backend runs the vectorised jnp
    flash (online-softmax, O(block) memory), on TPU the Pallas kernels
    — either way the same one-entry dispatch the model layer uses."""
    for K in Ks:
        family, cfgs, samplers, test = _tffn_cohort(K)
        train_us = {}
        for attn in TFFN_ATTN:
            for dtype in TFFN_DTYPES:
                base = FLRunConfig(method="fedadp", rounds=1,
                                   local_epochs=1, lr=0.05, momentum=0.9,
                                   eval_every=10 ** 9, engine="unified",
                                   attn_backend=attn, compute_dtype=dtype)
                sec, stats, train_s = _per_round(
                    family, cfgs, samplers, test, "unified", rounds,
                    base=base, agg_modes=("filler",))["filler"]
                train_us[(attn, dtype)] = train_s * 1e6
                csv.append(f"unified/tffn/K{K}/{attn}/{dtype},"
                           f"{sec * 1e6:.0f},us_train={train_s * 1e6:.0f} "
                           f"rounds={rounds}")
                records.append({"cohort": "tffn", "K": K,
                                "engine": "unified", "agg_mode": "filler",
                                "attn": attn, "compute_dtype": dtype,
                                "agg_layout": (stats or {}).get("layout"),
                                "us_per_round": round(sec * 1e6),
                                "us_train": round(train_s * 1e6),
                                "us_agg": round((sec - train_s) * 1e6),
                                "rounds": rounds})
        for dtype in TFFN_DTYPES:
            csv.append(
                f"unified/tffn/K{K}/flash_speedup/{dtype},"
                f"{train_us[('blockwise', dtype)] / max(train_us[('flash', dtype)], 1e-9):.2f},x")


AGG_LAYOUTS = ("leaf", "plane", "stream")
STREAM_K_CHUNK = 16                      # aggregation.default_k_chunk


def _agg_microbench(csv: List[str], records: List[dict], Ks, reps: int):
    """Aggregation-dominated rounds, all three layouts, each timed the
    way a ROUND actually executes it: ``leaf`` aggregates the resident
    stacked trees per leaf (the loop/tree path), ``plane`` runs one
    fused ``plane_agg`` pass on the RESIDENT packed plane, ``stream``
    consumes the resident plane in ``(k_chunk, P)`` row chunks through
    a ``PlaneAccumulator``. The unified engine trains in packed space
    and keeps the plane resident across rounds (packing is a one-time
    embed cost, not a per-round one — fl/engine.py), so pre-packing
    outside the timed loop is the per-round truth; the tree-interface
    adapter (``fedavg_stacked`` layout="plane"/"stream" on a stacked
    TREE) pays one pack per call on top. All on the union cohort's
    coverage average (masks + fallback — the heaviest variant the
    fused layouts fuse). This sweep carries the SCALE Ks (training
    rounds at K=128 are CI-prohibitive; the aggregation pass is the
    part the streaming layout scales) and the ``peak_agg_bytes``
    column."""
    import time

    import jax

    from repro.core import plane as planemod
    from repro.core.aggregation import (fedavg_stacked, global_shapes,
                                        stack_trees, subset_weights)
    from repro.fl.engine import UnifiedEngine
    from repro.kernels.fedavg import ops as kops
    from repro.kernels.fedavg.fedavg import on_tpu

    use_kernel = on_tpu()
    for K in Ks:
        # large-K cells keep the wall clock sane by cutting reps, not
        # coverage — every (K, agg_mode, layout) cell still runs
        reps_k = reps if K <= 16 else max(3, reps // 6)
        cfgs = [scaled(vgg(DEPTH_ARCHS[k % len(DEPTH_ARCHS)]), 0.125, 64)
                for k in range(K)]
        eng = UnifiedEngine(VGGFamily(), cfgs, [1] * K, method="fedadp",
                            agg_mode="coverage")
        shapes = global_shapes(eng.family, eng.global_cfg)
        n_leaves = len(jax.tree.leaves(shapes))
        key = jax.random.PRNGKey(0)

        def rand(i):
            leaves, td = jax.tree.flatten(shapes)
            return jax.tree.unflatten(td, [
                jax.random.normal(jax.random.fold_in(key, 97 * i + j),
                                  s.shape).astype(s.dtype)
                for j, s in enumerate(leaves)])

        stacked = stack_trees([rand(i) for i in range(K)])
        fallback = rand(K)
        w = subset_weights([1] * K)
        wj = jax.numpy.asarray(w, jax.numpy.float32)
        spec, _ = planemod.PlaneSpec.from_stacked(stacked)
        P = spec.size
        x_p = planemod.pack_stacked(stacked, spec, what="bench/x")
        m_p = planemod.pack_stacked(eng.cov_masks, spec, what="bench/m")
        fb_p = planemod.pack(fallback, spec, what="bench/fb")
        jax.block_until_ready((x_p, m_p, fb_p))
        kc = min(STREAM_K_CHUNK, K)

        def run_leaf(agg_mode):
            kw = ({} if agg_mode == "filler"
                  else dict(masks=eng.cov_masks, fallback=fallback))
            return fedavg_stacked(stacked, w, layout="leaf", **kw)

        def run_plane(agg_mode):
            kw = ({} if agg_mode == "filler"
                  else dict(masks=m_p, fallback=fb_p))
            return kops.plane_agg(x_p, wj, use_kernel=use_kernel, **kw)

        stream_stats = {}

        def run_stream(agg_mode):
            acc = kops.PlaneAccumulator(P, use_kernel=use_kernel,
                                        k_hint=kc)
            cov = agg_mode == "coverage"
            for lo in range(0, K, kc):
                hi = min(lo + kc, K)
                acc.update(x_p[lo:hi], wj[lo:hi],
                           masks=m_p[lo:hi] if cov else None)
            out = acc.finish(renorm=cov, fallback=fb_p if cov else None)
            stream_stats.update(acc.stats())
            return out

        for agg_mode in AGG_MODES:
            per = {}
            for layout in AGG_LAYOUTS:
                run = {"leaf": run_leaf, "plane": run_plane,
                       "stream": run_stream}[layout]
                out = run(agg_mode)
                jax.block_until_ready(out)          # pay compilation
                t0 = time.perf_counter()
                for _ in range(reps_k):
                    out = run(agg_mode)
                jax.block_until_ready(out)
                sec = (time.perf_counter() - t0) / reps_k
                per[layout] = sec
                dispatches = n_leaves if layout == "leaf" else 1
                peak = (stream_stats["peak_bytes"]
                        if layout == "stream" else 4 * K * P)
                csv.append(f"unified/agg/K{K}/{layout}/{agg_mode},"
                           f"{sec * 1e6:.0f},reps={reps_k}")
                records.append({"cohort": "agg", "K": K, "engine": "agg",
                                "agg_mode": agg_mode, "agg_layout": layout,
                                "us_per_call": round(sec * 1e6),
                                "dispatches": dispatches, "reps": reps_k,
                                "k_chunk": kc if layout == "stream"
                                else None,
                                "peak_agg_bytes": peak})
            csv.append(
                f"unified/agg/K{K}/speedup/{agg_mode},"
                f"{per['leaf'] / max(per['plane'], 1e-9):.2f},x")
            csv.append(
                f"unified/agg/K{K}/stream_speedup/{agg_mode},"
                f"{per['leaf'] / max(per['stream'], 1e-9):.2f},x")


WIRES = ("f32", "bf16", "int8", "int8+sparse")
WIRE_TILE = 256


def _wire_microbench(csv: List[str], records: List[dict], Ks, reps: int):
    """The quantized wire (ISSUE 9, DESIGN.md §10), timed the way the
    compressed round actually runs it: per ``(k_chunk, P)`` chunk, the
    client-side error-feedback encode (``engine._wire_encode`` — the
    same jit the round uses) then the server-side fold — ``update_q``
    (fused dequantize-accumulate, int8) or ``update`` (bf16/f32) — and
    one ``finish``. On the WIDTH cohort under the coverage average, so
    the sparse wire has real uncovered coordinates to drop. Every row
    carries ``bytes_per_round`` (client->server payload:
    ``core.quant.payload_nbytes``) next to ``us_per_call`` and
    ``peak_agg_bytes`` — the wire is a bytes-on-the-network
    optimization first, a wall-clock one second."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import plane as planemod
    from repro.core import quant
    from repro.core.aggregation import subset_weights
    from repro.fl.engine import UnifiedEngine, _wire_encode
    from repro.kernels.fedavg import ops as kops
    from repro.kernels.fedavg.fedavg import on_tpu

    use_kernel = on_tpu()
    for K in Ks:
        reps_k = reps if K <= 16 else max(3, reps // 6)
        cfgs = [scaled(vgg(WIDTH_ARCHS[k % len(WIDTH_ARCHS)]), 0.125, 64)
                for k in range(K)]
        eng = UnifiedEngine(VGGFamily(), cfgs, [1] * K, method="fedadp",
                            agg_mode="coverage")
        spec = eng.plane_spec
        P = spec.size
        key = jax.random.PRNGKey(0)
        x_p = jax.random.normal(jax.random.fold_in(key, K), (K, P),
                                jnp.float32)
        m_p = planemod.pack_stacked(eng.cov_masks, spec, what="bench/m")
        fb_p = jnp.zeros((P,), jnp.float32)
        wj = jnp.asarray(subset_weights([1] * K), jnp.float32)
        res = jnp.zeros((K, P), jnp.float32)
        covered = [int(c) for c in jax.device_get(m_p.sum(axis=1))]
        jax.block_until_ready((x_p, m_p, res))
        kc = min(STREAM_K_CHUNK, K)

        def run(wire):
            fmt = "int8" if wire.startswith("int8") else wire
            sparse = wire.endswith("sparse")
            acc = kops.PlaneAccumulator(
                P, use_kernel=use_kernel, k_hint=kc,
                q_tile=WIRE_TILE if fmt == "int8" else None)
            for lo in range(0, K, kc):
                hi = min(lo + kc, K)
                m = m_p[lo:hi]
                if fmt == "f32":
                    acc.update(x_p[lo:hi], wj[lo:hi], masks=m)
                    continue
                vals, scales, _ = _wire_encode(
                    x_p[lo:hi], res[lo:hi], m if sparse else None,
                    fmt=fmt, tile=WIRE_TILE)
                if fmt == "int8":
                    acc.update_q(vals, scales, wj[lo:hi], masks=m)
                else:
                    acc.update(vals, wj[lo:hi], masks=m)
            out = acc.finish(renorm=True, fallback=fb_p)
            return out, acc.stats()

        f32_bytes = 4 * K * P
        base_row = None
        for wire in WIRES:
            fmt = "int8" if wire.startswith("int8") else wire
            sparse = wire.endswith("sparse")
            out, stats = run(wire)
            jax.block_until_ready(out)              # pay compilation
            t0 = time.perf_counter()
            for _ in range(reps_k):
                out, stats = run(wire)
            jax.block_until_ready(out)
            sec = (time.perf_counter() - t0) / reps_k
            bytes_round = sum(
                quant.payload_nbytes(fmt, P, tile=WIRE_TILE,
                                     covered=covered[k] if sparse else None)
                for k in range(K))
            red = f32_bytes / bytes_round
            base_row = base_row if base_row is not None else sec
            csv.append(f"unified/wire/K{K}/{wire},{sec * 1e6:.0f},"
                       f"bytes_per_round={bytes_round} "
                       f"reduction={red:.2f}x")
            records.append({"cohort": "wire", "K": K, "engine": "agg",
                            "agg_mode": "coverage", "wire": wire,
                            "sparse": sparse,
                            "tile": WIRE_TILE if fmt == "int8" else None,
                            "us_per_call": round(sec * 1e6),
                            "bytes_per_round": bytes_round,
                            "f32_bytes": f32_bytes,
                            "reduction": round(red, 3), "reps": reps_k,
                            "k_chunk": kc,
                            "peak_agg_bytes": stats["peak_bytes"]})


def parse_ks(text: str):
    """Eagerly validate a ``--K`` comma list — bad input dies at
    argparse time, before any cohort builds or compiles."""
    import argparse
    parts = [p.strip() for p in text.split(",") if p.strip()]
    if not parts:
        raise argparse.ArgumentTypeError(
            f"--K {text!r}: expected a comma list of cohort sizes, "
            "e.g. --K 4,8,64")
    out = []
    for p in parts:
        try:
            k = int(p)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"--K {text!r}: {p!r} is not an int")
        if k < 1:
            raise argparse.ArgumentTypeError(
                f"--K {text!r}: cohort size {k} must be >= 1")
        out.append(k)
    return tuple(out)


def main(csv: List[str], Ks=None):
    import jax
    if _DEV and len(jax.devices()) != int(_DEV):
        # jax was initialized before this module could set XLA_FLAGS
        # (e.g. an earlier benchmarks/run.py section imported it) —
        # flag it so single-device rows aren't mistaken for sharded ones.
        csv.append(f"unified/devices,0,WARN=requested {_DEV} devices but "
                   f"jax has {len(jax.devices())}; run standalone or with "
                   "FEDADP_BENCH_ONLY=unified")
    smoke = os.environ.get("FEDADP_BENCH_SMOKE")
    full = os.environ.get("FEDADP_BENCH_FULL")
    if smoke:
        train_Ks, (n_per_client, batch, rounds) = (2,), (32, 16, 1)
        agg_Ks, agg_reps = (2, 64), 5     # K=64: one CI streaming row
        tffn_Ks, tffn_rounds = (8,), 2    # the CI flash-vs-blockwise cell
                                          # (2 timed rounds halve noise on
                                          # the us_train <= assertion)
    elif full:
        train_Ks, (n_per_client, batch, rounds) = (4, 8, 16), (256, 64, 5)
        agg_Ks, agg_reps = (4, 8, 16, 64, 128), 50
        tffn_Ks, tffn_rounds = (4, 8, 16), 5
    else:
        train_Ks, (n_per_client, batch, rounds) = (4, 8, 16), (64, 32, 3)
        agg_Ks, agg_reps = (4, 8, 16, 64, 128), 30
        tffn_Ks, tffn_rounds = (4, 8), 3
    if Ks:                               # --K overrides ALL sweeps
        train_Ks = agg_Ks = tffn_Ks = tuple(Ks)
    records = []
    for cohort, archs in COHORTS.items():
        prefix = "unified" if cohort == "depth" else f"unified/{cohort}"
        for K in train_Ks:
            family, cfgs, samplers, test = _cohort(K, n_per_client, batch,
                                                   archs)
            per = {}
            for engine in ("loop", "unified"):
                per[engine] = _per_round(family, cfgs, samplers, test,
                                         engine, rounds)
                for agg_mode, (sec, stats, train_s) in per[engine].items():
                    stats = stats or {}
                    split = ("" if train_s is None
                             else f"us_train={train_s * 1e6:.0f} ")
                    csv.append(f"{prefix}/K{K}/{engine}/{agg_mode},"
                               f"{sec * 1e6:.0f},{split}rounds={rounds}")
                    row = {"cohort": cohort, "K": K,
                           "engine": engine, "agg_mode": agg_mode,
                           "agg_layout": stats.get("layout", "tree"),
                           "us_per_round": round(sec * 1e6),
                           "rounds": rounds,
                           "k_chunk": stats.get("k_chunk"),
                           "peak_agg_bytes": stats.get("peak_bytes")}
                    if train_s is not None:
                        row["us_train"] = round(train_s * 1e6)
                        row["us_agg"] = round((sec - train_s) * 1e6)
                    records.append(row)
            for agg_mode in AGG_MODES:
                csv.append(
                    f"{prefix}/K{K}/speedup/{agg_mode},"
                    f"{per['loop'][agg_mode][0] / max(per['unified'][agg_mode][0], 1e-9):.2f},x")
    _tffn_bench(csv, records, tffn_Ks, tffn_rounds)
    _agg_microbench(csv, records, agg_Ks, agg_reps)
    _wire_microbench(csv, records, agg_Ks, agg_reps)
    path = os.environ.get("FEDADP_BENCH_JSON", "BENCH_unified.json")
    with open(path, "w") as f:
        json.dump({"bench": "unified_bench",
                   "protocol": {"rounds": rounds,
                                "n_per_client": n_per_client,
                                "batch": batch, "local_epochs": 1,
                                "smoke": bool(smoke), "full": bool(full),
                                "devices": len(jax.devices()),
                                "backend": jax.default_backend()},
                   "rows": records}, f, indent=1)
    csv.append(f"unified/json,0,{path}")
    return csv


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--K", type=parse_ks, default=None, metavar="K1,K2,...",
                    help="comma list of cohort sizes (overrides the "
                         "smoke/full/default sweeps; validated before "
                         "any work runs)")
    rows = main(["name,us_per_call,derived"], Ks=ap.parse_args().K)
    print("\n".join(rows))
