"""Federated data layer: dirichlet_partition feasibility guard and
ClientSampler tail-batch semantics (ISSUE 3 satellite bugfixes)."""
import numpy as np
import pytest

from repro.data.federated import ClientSampler, dirichlet_partition


# ------------------------------------------------------------- dirichlet
def test_dirichlet_partition_feasible_regression():
    labels = np.repeat(np.arange(4), 50)          # 200 samples, 4 classes
    parts = dirichlet_partition(labels, 4, alpha=0.5, seed=0, min_size=8)
    assert len(parts) == 4
    assert all(len(p) >= 8 for p in parts)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(200))


def test_dirichlet_partition_infeasible_raises_not_hangs():
    """k * min_size > n can never be satisfied: must raise a ValueError
    naming the offending parameters after the retry cap, not loop
    forever."""
    labels = np.repeat(np.arange(2), 5)           # 10 samples
    with pytest.raises(ValueError) as e:
        dirichlet_partition(labels, 5, alpha=0.5, seed=0, min_size=8,
                            max_retries=50)
    msg = str(e.value)
    assert "min_size=8" in msg and "k=5" in msg and "alpha=0.5" in msg


def test_dirichlet_partition_retry_cap_is_bounded():
    """A feasible-but-unlikely setting (alpha=0.01 concentrates whole
    classes on one client; only a perfectly balanced split passes) stops
    at the cap instead of spinning — seed 2's first draws all fail."""
    labels = np.repeat(np.arange(2), 8)           # 16 samples, k=2
    with pytest.raises(ValueError, match="max_retries|retries"):
        dirichlet_partition(labels, 2, alpha=0.01, seed=2, min_size=8,
                            max_retries=3)


# ---------------------------------------------------------- ClientSampler
def _data(n):
    return {"x": np.arange(n, dtype=np.float32), "y": np.zeros(n, np.int32)}


def _count(batches):
    sizes = [len(b["x"]) for b in batches]
    return len(sizes), sizes


def test_round_batches_pins_step_count_and_drops_nothing():
    """35 drawn samples at batch_size 16 -> 16,16,3 (tail >= min_batch
    kept); 33 -> 16,17 (1-sample tail merged into the previous batch).
    Either way every drawn sample is yielded exactly once per epoch."""
    for n, want_sizes in ((35, [16, 16, 3]), (33, [16, 17])):
        s = ClientSampler(_data(n), np.arange(n), round_fraction=1.0,
                          batch_size=16, seed=0)
        batches = list(s.round_batches())
        steps, sizes = _count(batches)
        assert sizes == want_sizes, (n, sizes)
        assert steps == s.steps_per_epoch()
        seen = np.sort(np.concatenate([b["x"] for b in batches]))
        np.testing.assert_array_equal(seen, np.arange(n, dtype=np.float32))


def test_round_batches_single_sample_client_contributes_a_step():
    """A client whose whole per-round draw is below min_batch used to be
    silently dropped (zero steps that round); now the draw is yielded
    as-is."""
    s = ClientSampler(_data(1), np.arange(1), round_fraction=1.0,
                      batch_size=16, seed=0)
    batches = list(s.round_batches(epochs=2))
    assert [len(b["x"]) for b in batches] == [1, 1]
    assert s.steps_per_epoch() == 1


def test_round_batches_epochs_and_exact_multiples_unchanged():
    s = ClientSampler(_data(64), np.arange(64), round_fraction=0.5,
                      batch_size=16, seed=1)
    batches = list(s.round_batches(epochs=2))
    assert [len(b["x"]) for b in batches] == [16, 16] * 2
    assert s.steps_per_epoch() == 2
