"""Integration: every FL method runs the paper protocol end to end on a
tiny VGG cohort, and FedADP's aggregation pipeline stays shape-coherent."""
import jax
import numpy as np
import pytest

from repro.configs.vgg_family import scaled, vgg
from repro.core import FedADP, VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator

FAMILY = VGGFamily()
ARCHS = ["vgg13", "vgg16-wider", "vgg19"]


def _mk_sim(method, rounds=1, **kw):
    cfgs = [scaled(vgg(a), 0.125, 32) for a in ARCHS]
    data = image_classification(EASY, 240, seed=0)
    test = image_classification(EASY, 60, seed=9)
    parts = iid_partition(240, len(cfgs), seed=0)
    samplers = [ClientSampler(data, p, round_fraction=0.4, batch_size=16,
                              seed=i) for i, p in enumerate(parts)]
    rc = FLRunConfig(method=method, rounds=rounds, local_epochs=1, lr=0.05,
                     **kw)
    return Simulator(FAMILY, cfgs, samplers, rc, test)


@pytest.mark.parametrize("method", ["fedadp", "flexifed", "clustered",
                                    "standalone"])
def test_method_runs_one_round(method):
    res = _mk_sim(method).run()
    assert len(res["history"]) == 1
    assert 0.0 <= res["history"][0] <= 1.0


def test_fedadp_global_shapes_stable_across_rounds():
    sim = _mk_sim("fedadp", rounds=2)
    res = sim.run()
    gp = res["global_params"]
    shapes0 = jax.tree.map(lambda l: l.shape, gp)
    algo = FedADP(FAMILY, sim.client_cfgs, sim.n_samples)
    gp2 = algo.round(gp, lambda k, p: p, 0)  # no-op local training
    assert jax.tree.map(lambda l: l.shape, gp2) == shapes0


def test_fedadp_noop_training_with_fold_is_fixed_pointish():
    """With fold narrowing and no local training, a round is FedAvg of
    function-preserving reconstructions — the global model's FUNCTION on
    covered structure is retained (weights may redistribute)."""
    sim = _mk_sim("fedadp", rounds=1, narrow_mode="fold")
    algo = FedADP(FAMILY, sim.client_cfgs, sim.n_samples,
                  narrow_mode="fold")
    gp = algo.init_global(jax.random.PRNGKey(0))
    gp2 = algo.round(gp, lambda k, p: p, 0)
    # structure identical; values finite
    assert jax.tree.map(lambda l: l.shape, gp2) == \
        jax.tree.map(lambda l: l.shape, gp)
    for leaf in jax.tree.leaves(gp2):
        assert np.isfinite(np.asarray(leaf)).all()


def test_fedadp_u_globalfill_runs():
    res = _mk_sim("fedadp", rounds=1, filler="global").run()
    assert len(res["history"]) == 1
