"""Substrate tests: data pipeline, optimizers, checkpointing, steps."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_pytree, save_pytree
from repro.data import (LMPipeline, dirichlet_partition, iid_partition,
                        image_classification, lm_sequences, EASY)
from repro.data.federated import ClientSampler
from repro.launch.steps import chunked_softmax_xent
from repro.optim import adamw, cosine_with_warmup, sgd


# ------------------------------------------------------------------- data
@given(n=st.integers(20, 500), k=st.integers(1, 10), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_iid_partition_covers_everything(n, k, seed):
    parts = iid_partition(n, k, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


def test_dirichlet_partition_is_skewed_and_complete():
    labels = np.random.default_rng(0).integers(0, 10, 2000)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=1)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == 2000
    # skew: at least one client's label histogram deviates from uniform
    h = np.bincount(labels[parts[0]], minlength=10) / len(parts[0])
    assert h.max() > 0.2


def test_lm_pipeline_deterministic_and_shifted():
    p1 = iter(LMPipeline(100, 4, 16, seed=3))
    p2 = iter(LMPipeline(100, 4, 16, seed=3))
    b1, b2 = next(p1), next(p2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_client_sampler_round_fraction():
    data = image_classification(EASY, 100, seed=0)
    s = ClientSampler(data, np.arange(100), round_fraction=0.2, batch_size=10)
    batches = list(s.round_batches(1))
    assert sum(len(b["y"]) for b in batches) == 20


def test_markov_source_is_learnable_structure():
    seqs = lm_sequences(50, 100, 32, seed=0)
    # successors are constrained: per-state successor entropy is bounded
    pairs = {}
    for row in seqs:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), set()).add(int(b))
    branch = np.mean([len(v) for v in pairs.values()])
    assert branch < 40  # far below vocab size => learnable


# ------------------------------------------------------------------ optim
def _rosenbrockish(p):
    return ((p["x"] - 1.0) ** 2).sum() + 5.0 * (p["y"] ** 2).sum()


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adamw(0.1)])
def test_optimizers_descend(opt):
    params = {"x": jnp.zeros(3), "y": jnp.ones(2)}
    state = opt.init(params)
    f0 = float(_rosenbrockish(params))
    for step in range(60):
        g = jax.grad(_rosenbrockish)(params)
        params, state = opt.update(g, state, params, step)
    assert float(_rosenbrockish(params)) < f0 * 0.05


def test_adamw_keeps_bf16_params_with_fp32_master():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw(1e-2)
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    new, state = opt.update(g, state, params, 0)
    assert new["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    s = cosine_with_warmup(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 1e-3


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip():
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": jnp.ones((4,), jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_pytree(path, tree, extra={"round": 7})
        loaded, extra = load_pytree(path, like=tree)
        assert extra["round"] == 7
        np.testing.assert_array_equal(np.asarray(loaded["a"]["b"]),
                                      np.asarray(tree["a"]["b"]))
        assert loaded["c"].dtype == jnp.bfloat16


# ------------------------------------------------------------------ steps
@pytest.mark.parametrize("chunk", [0, 4, 7])
def test_chunked_loss_matches_unchunked(chunk):
    key = jax.random.PRNGKey(0)
    B, S, D, V = 2, 12, 8, 50
    h = jax.random.normal(key, (B, S, D))
    w = jax.random.normal(jax.random.fold_in(key, 1), (D, V))
    labels = jax.random.randint(key, (B, S), 0, V)
    base, _ = chunked_softmax_xent(h, w, labels, chunk=0)
    got, _ = chunked_softmax_xent(h, w, labels, chunk=chunk)
    np.testing.assert_allclose(float(got), float(base), rtol=1e-5)
    # gradients agree too
    g0 = jax.grad(lambda h: chunked_softmax_xent(h, w, labels, chunk=0)[0])(h)
    g1 = jax.grad(lambda h: chunked_softmax_xent(h, w, labels,
                                                 chunk=chunk)[0])(h)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-4,
                               atol=1e-6)
