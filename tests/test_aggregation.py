"""FedAvg invariants (paper Eq. 1-2) — property-based."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (client_weights, fedavg, fedavg_stacked,
                                    stack_trees)


@given(ns=st.lists(st.integers(1, 1000), min_size=1, max_size=10))
@settings(max_examples=50, deadline=None)
def test_client_weights_normalized(ns):
    w = client_weights(ns)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
    assert (w >= 0).all()
    np.testing.assert_allclose(w, np.asarray(ns) / np.sum(ns), rtol=1e-5)


@given(k=st.integers(1, 6), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_fedavg_identity_and_convexity(k, seed):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (3, 4)), "b": {"c": jnp.ones((2,))}}
    w = client_weights([1] * k)
    # aggregating k copies of the same tree returns the tree
    agg = fedavg([tree] * k, w)
    np.testing.assert_allclose(np.asarray(agg["a"]), np.asarray(tree["a"]),
                               rtol=1e-5, atol=1e-6)
    # result is within the convex hull (elementwise min/max bound)
    import functools
    trees = [jax.tree.map(lambda x, i=i: x + i, tree) for i in range(k)]
    agg = fedavg(trees, w)
    lo = jax.tree.map(lambda *ls: functools.reduce(jnp.minimum, ls), *trees)
    hi = jax.tree.map(lambda *ls: functools.reduce(jnp.maximum, ls), *trees)
    assert bool(jnp.all(agg["a"] >= lo["a"] - 1e-5))
    assert bool(jnp.all(agg["a"] <= hi["a"] + 1e-5))


@given(k=st.integers(1, 5), use_kernel=st.booleans())
@settings(max_examples=10, deadline=None)
def test_stacked_matches_list(k, use_kernel):
    key = jax.random.PRNGKey(k)
    trees = [{"w": jax.random.normal(jax.random.fold_in(key, i), (6, 5))}
             for i in range(k)]
    w = client_weights(list(range(1, k + 1)))
    a = fedavg(trees, w)
    b = fedavg_stacked(stack_trees(trees), w, use_kernel=use_kernel)
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               rtol=1e-4, atol=1e-5)
