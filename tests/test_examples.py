"""Examples smoke: the documented entry points keep running after API
changes (tiny rounds/clients — correctness lives in the other suites)."""
import importlib.util
import os

import numpy as np

_EXAMPLES = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples")


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"examples_{name}", os.path.join(_EXAMPLES, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_smoke():
    res = _load("quickstart").main(
        rounds=1, local_epochs=1, eval_every=1, n=96, n_test=48, width=32,
        archs=("vgg13",), per_arch=2, methods=("fedadp",))
    assert set(res) == {"fedadp"}
    assert len(res["fedadp"]["history"]) == 1
    assert 0.0 <= res["fedadp"]["final_acc"] <= 1.0
    assert res["fedadp"]["global_params"] is not None


def test_unified_cohort_smoke():
    res = _load("unified_cohort").main(
        rounds=1, local_epochs=1, eval_every=1, width=32,
        archs=("vgg13", "vgg15"), per_arch=1, n_per_client=64, n_test=48)
    assert set(res) == {"loop", "unified"}
    # depth-only cohort: the two backends agree (exactness is pinned down
    # tighter in tests/test_unified.py)
    np.testing.assert_allclose(res["loop"]["history"],
                               res["unified"]["history"], atol=5e-3)
