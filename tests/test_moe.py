"""MoE: sort-based dispatch vs dense reference; capacity semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import moe as M

KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite


def _cfg(capacity=8.0, top_k=2, n_experts=4):
    cfg = reduced(get_config("mixtral-8x7b"), d_model=64)
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, n_experts=n_experts, top_k=top_k, capacity_factor=capacity))


def dense_ref(p, cfg, x):
    m = cfg.moe
    B, S, D = x.shape
    x2 = x.reshape(-1, D)
    wts, ids, _ = M._route(p["router"], x2, m.top_k)
    outs = []
    for e in range(m.n_experts):
        h = jax.nn.silu(x2 @ p["wg"][e]) * (x2 @ p["wu"][e])
        outs.append(h @ p["wd"][e])
    outs = jnp.stack(outs, 1)
    gate = jnp.zeros((x2.shape[0], m.n_experts)).at[
        jnp.arange(x2.shape[0])[:, None], ids].add(wts)
    return jnp.einsum("ne,ned->nd", gate, outs).reshape(B, S, D)


@pytest.mark.parametrize("top_k,n_experts", [(1, 4), (2, 4), (3, 3)])
def test_moe_matches_dense_when_capacity_ample(top_k, n_experts):
    cfg = _cfg(capacity=8.0, top_k=top_k, n_experts=n_experts)
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 9, cfg.d_model))
    got = M.moe_apply(p, cfg, x)
    want = dense_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_moe_drops_tokens_at_capacity():
    """With capacity_factor -> tiny, overflow tokens contribute nothing."""
    cfg = _cfg(capacity=0.01)
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model))
    got = M.moe_apply(p, cfg, x)
    want = dense_ref(p, cfg, x)
    # shapes fine, values differ (tokens dropped), nothing NaN
    assert got.shape == want.shape
    assert np.isfinite(np.asarray(got)).all()
    assert float(jnp.abs(got - want).max()) > 0


def test_moe_shared_experts_added():
    cfg = reduced(get_config("deepseek-v2-236b"), d_model=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    assert cfg.moe.n_shared >= 1
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 5, cfg.d_model))
    full = M.moe_apply(p, cfg, x)
    # zeroing shared-expert output weights removes their contribution
    p2 = dict(p, shared=jax.tree.map(jnp.zeros_like, p["shared"]))
    routed_only = M.moe_apply(p2, cfg, x)
    assert float(jnp.abs(full - routed_only).max()) > 0


def test_moe_grad_finite():
    cfg = _cfg()
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, cfg.d_model))

    def f(p):
        return (M.moe_apply(p, cfg, x) ** 2).sum()

    g = jax.grad(f)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
