"""Static analysis layer (repro.analysis; ISSUE 7).

Lint rules are exercised on inline source snippets (both directions:
the defect fires, the idiomatic fix is silent, a ``fedlint: ignore``
suppresses), the contract checker runs clean in quick mode, the kernel
validator runs clean on the real kernel surface AND detects a
deliberately broken case, and the CLI exits 0/1 accordingly.
"""
import textwrap

import jax
import jax.numpy as jnp

from repro.analysis import Finding, run
from repro.analysis import kernels_check, lint
from repro.analysis.__main__ import main as cli_main


def _lint(src):
    return lint.lint_source(textwrap.dedent(src), "snippet.py")


def _rules(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------- FDL001
def test_fdl001_key_reuse_fires():
    fs = _lint("""
        import jax
        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
    """)
    assert _rules(fs) == ["FDL001"]
    assert "key" in fs[0].msg


def test_fdl001_split_retires_key():
    assert _lint("""
        import jax
        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.normal(k2, (3,))
            return a + b
    """) == []


def test_fdl001_fold_in_and_loop():
    # fold_in per iteration is the idiom; reusing the loop key is not
    assert _lint("""
        import jax
        def ok(key, n):
            return [jax.random.normal(jax.random.fold_in(key, i), (2,))
                    for i in range(n)]
    """) == []
    fs = _lint("""
        import jax
        def bad(key, n):
            out = []
            for _ in range(n):
                out.append(jax.random.normal(key, (2,)))
            return out
    """)
    assert _rules(fs) == ["FDL001"]


def test_fdl001_exclusive_branches_do_not_sum():
    # if/else arms are exclusive paths — one use per arm is fine
    assert _lint("""
        import jax
        def f(key, flag):
            if flag:
                return jax.random.normal(key, (2,))
            else:
                return jax.random.uniform(key, (2,))
    """) == []


def test_fdl001_early_return_branch_does_not_leak():
    assert _lint("""
        import jax
        def f(key, flag):
            if flag:
                return jax.random.normal(key, (2,))
            return jax.random.uniform(key, (2,))
    """) == []


def test_fdl001_nonkey_names_exempt():
    # `key_pos` bound to a visibly non-random source is not a PRNG key
    assert _lint("""
        import jax.numpy as jnp
        def f(S):
            key_pos = jnp.arange(S)
            return key_pos + key_pos
    """) == []


def test_fdl001_suppression_comment():
    assert _lint("""
        import jax
        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))  # fedlint: ignore[FDL001]
            return a + b
    """) == []


# ------------------------------------------------------------- FDL002
def test_fdl002_mutable_jit_default():
    fs = _lint("""
        import jax
        @jax.jit
        def f(x, opts={}):
            return x
    """)
    assert _rules(fs) == ["FDL002"]
    assert _lint("""
        import jax
        @jax.jit
        def f(x, n=3):
            return x * n
    """) == []


# ------------------------------------------------------------- FDL003
def test_fdl003_import_time_device_work():
    fs = _lint("""
        import jax.numpy as jnp
        TABLE = jnp.arange(1024)
    """)
    assert _rules(fs) == ["FDL003"]
    # numpy at import time is fine; jnp inside functions is fine
    assert _lint("""
        import numpy as np
        import jax.numpy as jnp
        TABLE = np.arange(1024)
        def f():
            return jnp.arange(4)
    """) == []


# ------------------------------------------------------------- FDL004
def test_fdl004_python_branch_on_traced_value():
    fs = _lint("""
        import jax
        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert _rules(fs) == ["FDL004"]


def test_fdl004_static_args_and_shape_reads_exempt():
    assert _lint("""
        import jax
        from functools import partial
        @partial(jax.jit, static_argnames=("mode",))
        def f(x, mode):
            if mode == "fast":
                return x
            if x.ndim > 1:
                return x.sum(0)
            return -x
    """) == []


def test_findings_carry_location():
    fs = _lint("""
        import jax
        @jax.jit
        def f(x, opts={}):
            return x
    """)
    (f,) = fs
    assert f.where == "snippet.py" and f.line > 0
    assert "FDL002" in f.format()


# ----------------------------------------------------------- contracts
def test_contracts_quick_mode_clean():
    """The registry contract matrix (quick subset) holds: up/down shape
    preservation, segment coverage, mask algebra, plane round-trips."""
    report = run(["contracts"], quick=True)
    assert report.ok, "\n".join(f.format() for f in report.findings)
    assert report.checked["contracts"] >= 3   # vgg + 2 transformer archs


# ------------------------------------------------------------- kernels
def test_kernel_validator_clean_on_real_surface():
    findings, n = kernels_check.check_all()
    assert findings == [], "\n".join(f.format() for f in findings)
    assert n >= 12


def test_kernel_validator_detects_missing_kernel():
    """A wrapper that silently falls off the pallas path is a finding."""
    fs = kernels_check._case_findings(
        "fake", lambda x: x.sum(0), (jax.ShapeDtypeStruct((4, 8),
                                                          jnp.float32),),
        (8,))
    assert "no-kernel" in [f.rule for f in fs]


def test_kernel_validator_detects_pad_leak():
    """An output whose aval is the padded extent (not the caller's
    shape) is flagged — padded columns must never leak."""
    from repro.kernels.fedavg import ops
    n = 1000                          # lane-odd: padded to 1024 inside
    fs = kernels_check._case_findings(
        "padleak",
        lambda p, w: ops.plane_agg(p, w, use_kernel=True, interpret=True),
        (jax.ShapeDtypeStruct((4, n), jnp.float32),
         jax.ShapeDtypeStruct((4,), jnp.float32)),
        (1024,))                      # wrong on purpose: padded extent
    assert "pad-slice" in [f.rule for f in fs]


def test_kernel_validator_detects_vmem_blowout():
    """A block so large its double-buffered footprint exceeds the
    per-core VMEM budget is flagged before anything would launch."""
    from repro.kernels.fedavg import ops
    n = 1 << 22
    fs = kernels_check._case_findings(
        "vmem",
        lambda p, w: ops.plane_agg(p, w, block=1 << 21, use_kernel=True,
                                   interpret=True),
        (jax.ShapeDtypeStruct((8, n), jnp.float32),
         jax.ShapeDtypeStruct((8,), jnp.float32)),
        (n,))
    assert "vmem-budget" in [f.rule for f in fs]


# ----------------------------------------------------------------- CLI
def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("import numpy as np\nX = np.ones(3)\n")
    assert cli_main(["--pass", "lint", "--lint-root", str(clean)]) == 0
    out = capsys.readouterr().out
    assert "clean" in out

    dirty = tmp_path / "dirty.py"
    dirty.write_text("import jax.numpy as jnp\nX = jnp.ones(3)\n")
    assert cli_main(["--pass", "lint", "--lint-root", str(dirty)]) == 1
    out = capsys.readouterr().out
    assert "FDL003" in out


def test_report_api():
    f = Finding("lint", "FDL001", "x.py", 3, "msg")
    assert "x.py:3" in f.format() and "FDL001" in f.format()
    report = run(["lint"], lint_roots=["src/repro/analysis"])
    assert report.ok and report.checked["lint"] > 0
