"""Coverage-aware aggregation invariants (core.aggregation — the single
source of coverage semantics; ISSUE 3).

Property-style via seeded parametrized loops (no ``hypothesis`` on this
box):
  * per-coordinate renormalized weights sum to 1 wherever >= 1 client
    covers (the coverage-weighted average is convex there),
  * ``agg_mode="coverage"`` == plain FedAvg on homogeneous cohorts,
  * loose and strict coverage masks agree everywhere EXCEPT the
    identity-conv filler taps,
  * the masked Pallas kernel (interpret mode on CPU) == the jnp fallback
    to 1e-6.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg_family import VGGConfig, scaled, vgg
from repro.core import (FedADP, VGGFamily, client_weights,
                        coverage_and_filler, coverage_mask, fedavg,
                        fedavg_masked, fedavg_stacked, loosen, stack_trees,
                        subset_weights)

FAMILY = VGGFamily()


def _random_stack(key, k, shape):
    return jax.random.normal(key, (k,) + shape)


def _random_masks(key, k, shape, p=0.5):
    return (jax.random.uniform(key, (k,) + shape) < p).astype(jnp.float32)


# ------------------------------------------------- renormalization sums to 1
@pytest.mark.parametrize("seed", range(4))
def test_renormalized_weights_convex_where_covered(seed):
    """Wherever >= 1 client covers a coordinate, the effective
    per-coordinate weights w_k m_k / sum_j w_j m_j sum to 1 — checked by
    aggregating constant trees: the masked average of all-ones inputs
    must be exactly 1 on covered coordinates and equal the fallback on
    uncovered ones."""
    key = jax.random.PRNGKey(seed)
    k, shape = 3 + seed % 3, (5, 7)
    masks = _random_masks(jax.random.fold_in(key, 1), k, shape, p=0.4)
    w = client_weights(list(range(1, k + 1)))
    ones = jnp.ones((k,) + shape)
    out = fedavg_stacked({"x": ones}, w, masks={"x": masks},
                         fallback={"x": jnp.full(shape, -7.0)},
                         use_kernel=False)["x"]
    covered = np.asarray(masks).max(0) > 0
    np.testing.assert_allclose(np.asarray(out)[covered], 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out)[~covered], -7.0, atol=1e-6)
    # and the average of arbitrary inputs stays in the covering hull
    x = _random_stack(jax.random.fold_in(key, 2), k, shape)
    avg = np.asarray(fedavg_stacked({"x": x}, w, masks={"x": masks},
                                    use_kernel=False)["x"])
    xnp, mnp = np.asarray(x), np.asarray(masks)
    lo = np.where(mnp > 0, xnp, np.inf).min(axis=0)
    hi = np.where(mnp > 0, xnp, -np.inf).max(axis=0)
    assert np.all(avg[covered] >= lo[covered] - 1e-5)
    assert np.all(avg[covered] <= hi[covered] + 1e-5)


# ------------------------------------------- homogeneous == plain FedAvg
@pytest.mark.parametrize("seed", range(3))
def test_coverage_mode_equals_fedavg_on_homogeneous_cohort(seed):
    """On a cohort of identical architectures every mask is all-ones, so
    the HeteroFL-style renormalized average IS Eq. 1 — both at the
    aggregation level and through FedADP.aggregate."""
    key = jax.random.PRNGKey(100 + seed)
    cfg = _tiny("same", ((6,), (6, 6)))
    cfgs = [cfg, dataclasses.replace(cfg), dataclasses.replace(cfg)]
    trees = [FAMILY.init(jax.random.fold_in(key, i), cfg) for i in range(3)]
    n_samples = [2 + seed, 4, 1]
    plain = FedADP(FAMILY, cfgs, n_samples)
    cov = FedADP(FAMILY, cfgs, n_samples, agg_mode="coverage")
    gp = plain.init_global(jax.random.fold_in(key, 9))
    a = plain.aggregate(trees, round_idx=0, global_params=gp)
    b = cov.aggregate(trees, round_idx=0, global_params=gp)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)
    exp = fedavg(trees, client_weights(n_samples))
    for la, lb in zip(jax.tree.leaves(exp), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)


def test_subset_weights_renormalize():
    n = [10, 30, 20, 40]
    np.testing.assert_allclose(subset_weights(n), client_weights(n))
    np.testing.assert_allclose(subset_weights(n, [1, 3]), [0.3 / 0.7, 0.4 / 0.7],
                               rtol=1e-6)
    np.testing.assert_allclose(subset_weights(n, [2]), [1.0])


# --------------------------------------------------- loose vs strict masks
def _tiny(name, stages):
    return VGGConfig(name=name, stages=stages, classifier=(12,),
                     n_classes=4, image_size=8)


@pytest.mark.parametrize("archs", [("vgg13", "vgg16"), ("vgg13", "vgg19")])
def test_loose_strict_divergence_is_exactly_identity_taps(archs):
    """loose - strict is 0/1, nonzero ONLY where the filler is nonzero
    (identity-conv center taps), and ``loosen`` reproduces the loose
    policy of ``coverage_mask`` exactly."""
    cfgs = [scaled(vgg(a), 0.125, 16) for a in archs]
    gcfg = FAMILY.union(cfgs)
    for cfg in cfgs:
        strict, filler = coverage_and_filler(FAMILY, cfg, gcfg)
        loose = coverage_mask(FAMILY, cfg, gcfg, policy="loose")
        loose2 = loosen(strict, filler)
        for a, b in zip(jax.tree.leaves(loose), jax.tree.leaves(loose2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for lm, sm, fl in zip(jax.tree.leaves(loose), jax.tree.leaves(strict),
                              jax.tree.leaves(filler)):
            diff = np.asarray(lm) - np.asarray(sm)
            assert set(np.unique(diff)) <= {0.0, 1.0}
            np.testing.assert_array_equal(
                diff > 0, (np.abs(np.asarray(fl)) > 0) & (np.asarray(sm) == 0))


def test_full_depth_client_is_fully_covered_under_both_policies():
    cfgs = [_tiny("a", ((6,), (6,))), _tiny("b", ((6, 6), (6, 6)))]
    gcfg = FAMILY.union(cfgs)
    for policy in ("loose", "strict"):
        m = coverage_mask(FAMILY, cfgs[1], gcfg, policy=policy)
        assert min(float(x.min()) for x in jax.tree.leaves(m)) == 1.0


# --------------------------------------------------- kernel vs jnp fallback
@pytest.mark.parametrize("renorm", [True, False])
def test_weighted_sum_masked_kernel_matches_jnp(renorm):
    """Masked Pallas kernel (interpret mode on CPU) == jnp fallback to
    1e-6, on a pytree with lane-unaligned leaf shapes (exercises the pad
    path; padded coordinates are uncovered by construction)."""
    key = jax.random.PRNGKey(0)
    trees, masks = [], []
    for k in range(3):
        kk = jax.random.fold_in(key, k)
        trees.append({
            "w": jax.random.normal(kk, (7, 13)),
            "b": jax.random.normal(jax.random.fold_in(kk, 1), (5,)),
            "c": jax.random.normal(jax.random.fold_in(kk, 2), (2, 3, 128)),
        })
        masks.append(jax.tree.map(
            lambda x: (jax.random.uniform(jax.random.fold_in(kk, 3),
                                          x.shape) < 0.6).astype(jnp.float32),
            trees[-1]))
    stacked, smasks = stack_trees(trees), stack_trees(masks)
    w = client_weights([3, 1, 2])
    a = fedavg_stacked(stacked, w, masks=smasks, renorm=renorm,
                       use_kernel=True)
    b = fedavg_stacked(stacked, w, masks=smasks, renorm=renorm,
                       use_kernel=False)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)
    # fedavg_masked (list layout) is the same math
    c = fedavg_masked(trees, w, masks, renorm=renorm, use_kernel=True)
    for la, lb in zip(jax.tree.leaves(c), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)


@pytest.mark.parametrize("renorm", [True, False])
def test_weighted_sum_masked_mult_kernel_matches_jnp(renorm):
    """Multiplicity-weighted masked kernel (per-coordinate client weight
    W_k m_k / mult_k, interpret mode on CPU) == the jnp fallback AND the
    pure-jnp oracle to 1e-6, on lane-unaligned leaf shapes (pad path:
    mult's zero padding must be neutral)."""
    from repro.kernels.fedavg import ops as kops
    from repro.kernels.fedavg.ref import weighted_sum_masked_ref
    key = jax.random.PRNGKey(2)
    trees, masks, mults = [], [], []
    for k in range(3):
        kk = jax.random.fold_in(key, k)
        trees.append({
            "w": jax.random.normal(kk, (7, 13)),
            "b": jax.random.normal(jax.random.fold_in(kk, 1), (5,)),
            "c": jax.random.normal(jax.random.fold_in(kk, 2), (2, 3, 128)),
        })
        masks.append(jax.tree.map(
            lambda x: (jax.random.uniform(jax.random.fold_in(kk, 3),
                                          x.shape) < 0.6).astype(jnp.float32),
            trees[-1]))
        mults.append(jax.tree.map(
            lambda x: jax.random.randint(jax.random.fold_in(kk, 4),
                                         x.shape, 1, 4).astype(jnp.float32),
            trees[-1]))
    stacked, smasks, smults = (stack_trees(trees), stack_trees(masks),
                               stack_trees(mults))
    w = client_weights([3, 1, 2])
    a = fedavg_stacked(stacked, w, masks=smasks, mult=smults, renorm=renorm,
                       use_kernel=True)
    b = fedavg_stacked(stacked, w, masks=smasks, mult=smults, renorm=renorm,
                       use_kernel=False)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)
    # against the 2-D oracle, leaf by leaf
    for name in ("w", "b", "c"):
        x = stacked[name].reshape(3, -1)
        m = smasks[name].reshape(3, -1)
        mu = smults[name].reshape(3, -1)
        ref = weighted_sum_masked_ref(x, jnp.asarray(w), m, mult=mu,
                                      renorm=renorm)
        got = kops.weighted_sum_masked(stacked[name], jnp.asarray(w),
                                       smasks[name], mult=smults[name],
                                       renorm=renorm)
        np.testing.assert_allclose(np.asarray(got).reshape(-1),
                                   np.asarray(ref), atol=1e-6)
    # all-ones multiplicity reduces to the plain masked average
    ones = jax.tree.map(jnp.ones_like, smults)
    c = fedavg_stacked(stacked, w, masks=smasks, mult=ones, renorm=renorm,
                       use_kernel=True)
    d = fedavg_stacked(stacked, w, masks=smasks, renorm=renorm,
                       use_kernel=True)
    for la, lb in zip(jax.tree.leaves(c), jax.tree.leaves(d)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)


def test_multiplicity_weight_splits_across_duplicates():
    """The semantic in one picture: client A covers a coordinate pair as
    TWO duplicates of one channel (mult 2), client B covers each with a
    distinct channel (mult 1). With renorm, A's effective weight per
    coordinate halves: out = (w_A/2·x_A + w_B·x_B) / (w_A/2 + w_B)."""
    x = jnp.asarray([[2.0, 2.0], [6.0, 6.0]])
    m = jnp.ones((2, 2))
    mu = jnp.asarray([[2.0, 2.0], [1.0, 1.0]])
    w = jnp.asarray([0.5, 0.5])
    out = fedavg_stacked({"x": x}, w, masks={"x": m}, mult={"x": mu},
                         use_kernel=False)["x"]
    want = (0.25 * 2.0 + 0.5 * 6.0) / 0.75
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


def test_all_ones_masks_reduce_to_plain_fedavg():
    key = jax.random.PRNGKey(5)
    stacked = {"w": jax.random.normal(key, (4, 6, 9))}
    masks = jax.tree.map(jnp.ones_like, stacked)
    w = client_weights([1, 2, 3, 4])
    a = fedavg_stacked(stacked, w, masks=masks, use_kernel=False)
    b = fedavg_stacked(stacked, w, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               atol=1e-6)
