"""Unified-space simulation == literal FedADP for depth-only cohorts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import FedADP, TransformerFamily, tfamily
from repro.fl.unified import UnifiedFedADP
from repro.launch.steps import lm_loss


def _setup():
    base = reduced(get_config("glm4-9b"), n_units=2, d_model=64)
    variants = [tfamily.make_variant(base, n_units=2),
                tfamily.make_variant(base, n_units=1)]
    family = TransformerFamily()
    gcfg = family.union(variants)

    def loss(params, batch):
        return lm_loss(params, gcfg, batch)[0]

    return family, variants, gcfg, loss


def _batches(vocab, K=2, steps=2, B=2, S=8):
    key = jax.random.PRNGKey(7)
    out = []
    for s in range(steps):
        toks = jax.random.randint(jax.random.fold_in(key, s),
                                  (K, B, S + 1), 0, vocab)
        out.append({"tokens": toks[..., :-1], "labels": toks[..., 1:]})
    return out


def test_unified_matches_literal_for_depth_cohort():
    family, variants, gcfg, loss = _setup()
    uni = UnifiedFedADP(family, variants, [1, 1], loss, lr=0.05)
    gp = uni.init_global(jax.random.PRNGKey(3))
    batches = _batches(gcfg.vocab_size)

    new_unified = uni.round(gp, batches)

    # literal FedADP, fold mode, same SGD steps on the same batches
    algo = FedADP(family, variants, [1, 1], narrow_mode="fold", base_seed=0)

    def local_train(k, params):
        cfg = variants[k]

        def closs(p, b):
            return lm_loss(p, cfg, b)[0]

        for batch in batches:
            b_k = jax.tree.map(lambda x: x[k], batch)
            g = jax.grad(closs)(params, b_k)
            params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        return params

    new_literal = algo.round(gp, local_train, 0)

    # depth-only heterogeneity: must agree to numerical precision.
    # literal round 0 distributes global -> client (fold) which is exact
    # for full-depth client 0 and a slice for client 1; the unified mask
    # replicates precisely that structure.
    for a, b in zip(jax.tree.leaves(new_unified), jax.tree.leaves(new_literal)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_unified_mask_structure():
    family, variants, gcfg, loss = _setup()
    uni = UnifiedFedADP(family, variants, [1, 1], loss)
    # client 0 covers everything; client 1 has zero masks on unit 2 only
    m0 = jax.tree.map(lambda m: float(m[0].min()), uni.masks)
    assert min(jax.tree.leaves(m0)) == 1.0
    wq_mask = uni.masks["units"]["b0"]["attn"]["wq"]
    assert float(wq_mask[1, 0].min()) == 1.0     # unit 1 covered
    assert float(wq_mask[1, 1].max()) == 0.0     # unit 2 masked for client 1
