"""Unified-space simulation == literal FedADP for depth-only cohorts.

Two layers of evidence:
  * UnifiedFedADP (transformer family) vs a hand-rolled literal round,
  * the full cohort-parallel engine behind ``Simulator(engine="unified")``
    vs the per-client reference loop — same data, same SGD+momentum,
    matching global parameters to atol 1e-5 on a depth-heterogeneous VGG
    cohort — plus kernel/jnp ``fedavg_stacked`` agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.vgg_family import scaled, vgg
from repro.core import (FedADP, TransformerFamily, VGGFamily, client_weights,
                        fedavg_stacked, stack_trees, tfamily)
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator
from repro.fl.engine import UnifiedEngine
from repro.fl.unified import UnifiedFedADP
from repro.launch.steps import lm_loss


def _setup():
    base = reduced(get_config("glm4-9b"), n_units=2, d_model=64)
    variants = [tfamily.make_variant(base, n_units=2),
                tfamily.make_variant(base, n_units=1)]
    family = TransformerFamily()
    gcfg = family.union(variants)

    def loss(params, batch):
        return lm_loss(params, gcfg, batch)[0]

    return family, variants, gcfg, loss


def _batches(vocab, K=2, steps=2, B=2, S=8):
    key = jax.random.PRNGKey(7)
    out = []
    for s in range(steps):
        toks = jax.random.randint(jax.random.fold_in(key, s),
                                  (K, B, S + 1), 0, vocab)
        out.append({"tokens": toks[..., :-1], "labels": toks[..., 1:]})
    return out


def test_unified_matches_literal_for_depth_cohort():
    family, variants, gcfg, loss = _setup()
    uni = UnifiedFedADP(family, variants, [1, 1], loss, lr=0.05)
    gp = uni.init_global(jax.random.PRNGKey(3))
    batches = _batches(gcfg.vocab_size)

    new_unified = uni.round(gp, batches)

    # literal FedADP, fold mode, same SGD steps on the same batches
    algo = FedADP(family, variants, [1, 1], narrow_mode="fold", base_seed=0)

    def local_train(k, params):
        cfg = variants[k]

        def closs(p, b):
            return lm_loss(p, cfg, b)[0]

        for batch in batches:
            b_k = jax.tree.map(lambda x: x[k], batch)
            g = jax.grad(closs)(params, b_k)
            params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        return params

    new_literal = algo.round(gp, local_train, 0)

    # depth-only heterogeneity: must agree to numerical precision.
    # literal round 0 distributes global -> client (fold) which is exact
    # for full-depth client 0 and a slice for client 1; the unified mask
    # replicates precisely that structure.
    for a, b in zip(jax.tree.leaves(new_unified), jax.tree.leaves(new_literal)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_unified_mask_structure():
    family, variants, gcfg, loss = _setup()
    uni = UnifiedFedADP(family, variants, [1, 1], loss)
    # client 0 covers everything; client 1 has zero masks on unit 2 only
    m0 = jax.tree.map(lambda m: float(m[0].min()), uni.masks)
    assert min(jax.tree.leaves(m0)) == 1.0
    wq_mask = uni.masks["units"]["b0"]["attn"]["wq"]
    assert float(wq_mask[1, 0].min()) == 1.0     # unit 1 covered
    assert float(wq_mask[1, 1].max()) == 0.0     # unit 2 masked for client 1


# ------------------------------------------------ cohort-parallel engine

def _vgg_setup(archs, n=240, *, seed=0):
    family = VGGFamily()
    cfgs = [scaled(vgg(a), 0.125, 64) for a in archs]
    data = image_classification(EASY, n, seed=seed)
    test = image_classification(EASY, 120, seed=99)
    parts = iid_partition(n, len(cfgs), seed=seed)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=32,
                              seed=i) for i, p in enumerate(parts)]

    return family, cfgs, samplers, test


def _run_both(family, cfgs, samplers, test, method, *, rounds=1):
    out = {}
    for eng in ("loop", "unified"):
        rc = FLRunConfig(method=method, rounds=rounds, local_epochs=1,
                         lr=0.05, momentum=0.9, eval_every=1, engine=eng)
        out[eng] = Simulator(family, cfgs, samplers(), rc, test).run()
    return out["loop"], out["unified"]


def test_engine_fedadp_round_matches_simulator_loop():
    """Depth-heterogeneous VGG cohort: the unified engine's FedADP round —
    stacked momentum state, mask-projected grads, stacked FedAvg — must
    reproduce the per-client reference loop's GLOBAL parameters."""
    family, cfgs, samplers, test = _vgg_setup(("vgg13", "vgg16", "vgg19"))
    assert family.depth_only(cfgs)
    loop, uni = _run_both(family, cfgs, samplers, test, "fedadp")
    assert loop["history"] == uni["history"]
    for a, b in zip(jax.tree.leaves(loop["global_params"]),
                    jax.tree.leaves(uni["global_params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


@pytest.mark.parametrize("method", ["clustered", "flexifed"])
def test_engine_cluster_methods_match_simulator_loop(method):
    """Per-cluster (and FlexiFed prefix+cluster) aggregation in unified
    space == the literal baselines: client functions (logits) agree; loop
    params are client-space, engine params are the embedded global-space
    views."""
    from repro.models import vgg as V
    family, cfgs, samplers, test = _vgg_setup(
        ("vgg13", "vgg13", "vgg19", "vgg19"), n=320)
    loop, uni = _run_both(family, cfgs, samplers, test, method)
    assert loop["history"] == uni["history"]
    gcfg = family.union(cfgs)
    for k in range(len(cfgs)):
        la = V.apply(loop["client_params"][k], cfgs[k], test["x"][:16])
        lb = V.apply(uni["client_params"][k], gcfg, test["x"][:16])
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)


def test_engine_flexifed_prefix_grouping():
    """FlexiFed grouping from configs alone: the shared prefix stops at the
    first depth divergence in chain order — vgg13 has 2 convs in stage 2
    vs vgg19's 4, so the prefix is the 6 convs of stages 0-1 plus s2's
    first two, and nothing beyond."""
    family = VGGFamily()
    cfgs = [scaled(vgg(a), 0.125, 64) for a in ("vgg13", "vgg19")]
    eng = UnifiedEngine(family, cfgs, [1, 1], method="flexifed")
    paths = eng._prefix_paths
    assert ("stages", "s0", "c0") in paths and ("stages", "s1", "c1") in paths
    assert ("stages", "s2", "c1") in paths
    assert ("stages", "s2", "c2") not in paths
    assert not any(p[:2] == ("stages", "s3") for p in paths)
    assert ("out",) not in paths


def test_fedavg_stacked_kernel_matches_jnp():
    """Pallas kernel path (interpret on CPU) == jnp einsum fallback, on a
    pytree with lane-unaligned leaf shapes (exercises the pad path)."""
    key = jax.random.PRNGKey(0)
    trees = []
    for k in range(3):
        kk = jax.random.fold_in(key, k)
        trees.append({
            "w": jax.random.normal(kk, (7, 13)),
            "b": jax.random.normal(jax.random.fold_in(kk, 1), (5,)),
            "c": jax.random.normal(jax.random.fold_in(kk, 2), (2, 3, 128)),
        })
    stacked = stack_trees(trees)
    w = client_weights([3, 1, 2])
    a = fedavg_stacked(stacked, w, use_kernel=True)
    b = fedavg_stacked(stacked, w, use_kernel=False)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)
