"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle,
across shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite


# ----------------------------------------------------------------- fedavg
@pytest.mark.parametrize("k", [1, 3, 20])
@pytest.mark.parametrize("n", [128, 1000, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_kernel_sweep(k, n, dtype):
    from repro.kernels.fedavg import ops, ref
    x = jax.random.normal(KEY, (k, n), dtype=dtype)
    w = jax.random.uniform(jax.random.fold_in(KEY, 1), (k,))
    w = w / w.sum()
    got = ops.weighted_sum(x, w)
    want = ref.weighted_sum_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_fedavg_kernel_nd_shapes():
    from repro.kernels.fedavg import ops, ref
    x = jax.random.normal(KEY, (4, 3, 5, 7))
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    got = ops.weighted_sum(x, w)
    want = ref.weighted_sum_ref(x.reshape(4, -1), w).reshape(3, 5, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# -------------------------------------------------------------- netchange
@pytest.mark.parametrize("rows,old,new", [(7, 30, 50), (64, 128, 256),
                                          (5, 3, 100), (300, 260, 261)])
@pytest.mark.parametrize("split", [False, True])
def test_widen_kernel_sweep(rows, old, new, split):
    from repro.core.netchange import dup_mapping
    from repro.kernels.netchange import ops, ref
    x = jax.random.normal(KEY, (rows, old))
    mapping = dup_mapping(old, new, tag="k", seed=3)
    got = ops.widen_cols(x, mapping, split=split)
    counts = np.bincount(mapping, minlength=old)
    scale = (1.0 / counts[mapping]).astype(np.float32) if split \
        else np.ones(new, np.float32)
    want = ref.widen_ref(x, jnp.asarray(mapping), jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_widen_kernel_matches_core_semantics():
    """Kernel == repro.core.netchange.widen_in/out on real weights."""
    from repro.core import netchange as nc
    from repro.kernels.netchange import ops
    w = jax.random.normal(KEY, (40, 24))
    m = nc.dup_mapping(24, 40, tag="q", seed=7)
    np.testing.assert_allclose(
        np.asarray(ops.widen_cols(w, m, split=False)),
        np.asarray(nc.widen_in(w, m, axis=-1)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ops.widen_cols(w, m, split=True)),
        np.asarray(nc.widen_out(w.T, m, 24, axis=0).T), rtol=1e-6)


# ---------------------------------------------------------- swa attention
@pytest.mark.parametrize("B,H,KV,hd,S", [(1, 4, 1, 64, 256), (2, 8, 2, 32, 384),
                                         (3, 6, 6, 128, 128)])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_decode_sweep(B, H, KV, hd, S, window, dtype):
    from repro.kernels.swa_attention import ops, ref
    q = jax.random.normal(KEY, (B, H, hd), dtype=dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd), dtype=dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd), dtype=dtype)
    key_pos = jnp.arange(S)
    pos = jnp.int32(S - 10)
    got = ops.decode_attention(q, k, v, key_pos, pos, window=window,
                               block_s=128)
    want = ref.decode_ref(q.reshape(B, KV, H // KV, hd), k, v, key_pos, pos,
                          window=window).reshape(B, H, hd)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_swa_decode_ring_cache_positions():
    """Ring-buffer caches: unwritten slots (< 0) are masked out."""
    from repro.kernels.swa_attention import ops, ref
    from repro.models.attention import ring_positions
    B, H, KV, hd, W = 1, 2, 1, 32, 128
    pos = jnp.int32(37)                     # ring only partially written
    key_pos = ring_positions(pos, W)
    assert int((key_pos >= 0).sum()) == 38
    q = jax.random.normal(KEY, (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, W, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, W, KV, hd))
    got = ops.decode_attention(q, k, v, key_pos, pos, window=W, block_s=64)
    want = ref.decode_ref(q.reshape(B, KV, H, hd), k, v, key_pos, pos,
                          window=W).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("B,KV,G,S,hd,win,bq,bk",
                         [(1, 2, 2, 256, 32, 64, 64, 64),
                          (2, 1, 4, 512, 64, 128, 128, 64),
                          (1, 2, 1, 256, 32, 0, 64, 64),   # full causal
                          (1, 1, 2, 128, 16, 16, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_prefill_kernel_sweep(B, KV, G, S, hd, win, bq, bk, dtype):
    from repro.kernels.swa_attention.prefill import swa_prefill
    from repro.kernels.swa_attention.ref import prefill_ref
    q = jax.random.normal(KEY, (B, KV, G, S, hd), dtype=dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd),
                          dtype=dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd),
                          dtype=dtype)
    got = swa_prefill(q, k, v, window=win, block_q=bq, block_kv=bk)
    want = prefill_ref(q, k, v, window=win)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_swa_kernel_vs_model_decode_attention():
    """Pallas kernel == the model-side XLA decode attention path."""
    from repro.kernels.swa_attention import ops
    from repro.models.attention import decode_attention as xla_decode
    B, H, KV, hd, S = 2, 8, 4, 64, 256
    q = jax.random.normal(KEY, (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, KV, hd))
    key_pos = jnp.arange(S)
    pos = jnp.int32(S - 1)
    got = ops.decode_attention(q, k, v, key_pos, pos, window=128)
    want = xla_decode(q, k, v, key_pos, pos, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
