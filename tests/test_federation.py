"""Federation orchestrator: checkpoint/resume fidelity, participation
schedules, partial-participation semantics, eager config validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import (Federation, FLRunConfig, FedADPStrategy, LoopBackend,
                      Participation, Simulator, UnifiedBackend,
                      checkpoint_path, load_round_checkpoint, make_strategy,
                      restore_sampler_rngs, save_round_checkpoint,
                      unified_eligible, unified_ineligible_reason)

FAMILY = VGGFamily()


def _setup(archs=("vgg13", "vgg16"), n=160, width=32):
    cfgs = [scaled(vgg(a), 0.125, width) for a in archs]
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, 80, seed=9)
    parts = iid_partition(n, len(cfgs), seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=16,
                              seed=i) for i, p in enumerate(parts)]

    return cfgs, samplers, test


def _backend(kind, cfgs, samplers):
    cls = UnifiedBackend if kind == "unified" else LoopBackend
    return cls(FAMILY, cfgs, samplers, local_epochs=1, lr=0.05, momentum=0.9)


# --------------------------------------------------------------- resume
@pytest.mark.parametrize("kind", ["loop", "unified"])
def test_checkpoint_resume_reproduces_run(kind, tmp_path):
    """Interrupt a 6-round fedadp run at round 3, restore, and the resumed
    history + final global params match the uninterrupted run (the
    checkpoint carries round, state, and the samplers' rng streams)."""
    cfgs, mk, test = _setup()
    backend = _backend(kind, cfgs, mk())   # one backend: jit caches shared

    def fed(rounds, **kw):
        strategy = FedADPStrategy(FAMILY, cfgs,
                                  [s.n_samples for s in backend.samplers])
        return Federation(strategy, backend, rounds=rounds, eval_batch=test,
                          eval_every=1, **kw)

    key = jax.random.PRNGKey(0)
    full = fed(6).run(key)

    ckdir = str(tmp_path / kind)
    backend.samplers = mk()                # fresh stream = a fresh 6-round job
    fed(3, checkpoint_dir=ckdir, checkpoint_every=3).run(key)   # "interrupt"  # fedlint: ignore[FDL001] resume must replay the SAME stream
    backend.samplers = mk()                # resumed process starts cold...
    resumed = fed(6).run(key, resume_from=checkpoint_path(ckdir, 3))

    np.testing.assert_allclose(resumed["history"], full["history"], atol=1e-6)
    assert len(resumed["history"]) == 6
    for a, b in zip(jax.tree.leaves(full["global_params"]),
                    jax.tree.leaves(resumed["global_params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_resume_reproduces_compressed_run(tmp_path):
    """ISSUE 9: a wire="int8" run carries per-client error-feedback
    residuals between rounds. The checkpoint writes them to a sibling
    ``round_XXXX.wire.npz`` (bit-exact raw views) and resume restores
    them, so the resumed compressed run matches the uninterrupted one."""
    from repro.fl.federation import wire_checkpoint_path

    cfgs, mk, test = _setup()
    backend = UnifiedBackend(FAMILY, cfgs, mk(), local_epochs=1, lr=0.05,
                             momentum=0.9, wire="int8")

    def fed(rounds, **kw):
        strategy = FedADPStrategy(FAMILY, cfgs,
                                  [s.n_samples for s in backend.samplers])
        return Federation(strategy, backend, rounds=rounds, eval_batch=test,
                          eval_every=1, **kw)

    key = jax.random.PRNGKey(0)
    full = fed(6).run(key)

    ckdir = str(tmp_path / "wire")
    backend.samplers = mk()
    fed(3, checkpoint_dir=ckdir, checkpoint_every=3).run(key)   # "interrupt"  # fedlint: ignore[FDL001] resume must replay the SAME stream
    ck = checkpoint_path(ckdir, 3)
    wp = wire_checkpoint_path(ck)
    assert wp.endswith("round_0003.wire.npz")
    import os
    assert os.path.exists(wp), "compressed run must checkpoint residuals"
    # after 3 rounds of int8 quantization the residuals are nonzero —
    # dropping them on resume would NOT bit-match the uninterrupted run
    res = backend.wire_residuals()
    assert float(jnp.abs(res).max()) > 0.0

    backend.engine = None                  # resumed process starts cold
    backend._engine_key = None
    backend.samplers = mk()
    resumed = fed(6).run(key, resume_from=ck)

    np.testing.assert_allclose(resumed["history"], full["history"], atol=1e-6)
    assert len(resumed["history"]) == 6
    for a, b in zip(jax.tree.leaves(full["global_params"]),
                    jax.tree.leaves(resumed["global_params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


class _FakeSampler:
    def __init__(self, seed):
        self.rng = np.random.default_rng(seed)


def test_checkpoint_bf16_and_rng_roundtrip(tmp_path):
    """bf16 leaves survive the npz uint16 view round-trip, and restored
    sampler rngs continue the stream exactly where the checkpoint cut it."""
    state = {"w": (jnp.arange(6, dtype=jnp.bfloat16) / 3).reshape(2, 3),
             "b": jnp.ones((4,), jnp.float32)}
    s = _FakeSampler(5)
    s.rng.integers(0, 10, size=7)                    # advance the stream
    path = str(tmp_path / "ck.npz")
    save_round_checkpoint(path, state, round_idx=2, history=[0.1, 0.2],
                          samplers=[s])
    expected_next = s.rng.integers(0, 1000, size=8)  # post-checkpoint draws

    like = jax.tree.map(jnp.zeros_like, state)
    state2, extra = load_round_checkpoint(path, like=like)
    assert extra["round"] == 2 and extra["history"] == [0.1, 0.2]
    assert state2["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(state2["w"], np.float32),
                                  np.asarray(state["w"], np.float32))
    s2 = _FakeSampler(0)                             # wrong seed on purpose
    restore_sampler_rngs([s2], extra)
    np.testing.assert_array_equal(s2.rng.integers(0, 1000, size=8),
                                  expected_next)


# -------------------------------------------------------- participation
def test_participation_schedules():
    p = Participation.sample(0.5, seed=1)
    sels = [p.select(r, 6) for r in range(5)]
    assert all(len(s) == 3 and s == sorted(set(s)) for s in sels)
    # deterministic in (seed, round) and varying across rounds
    assert [Participation.sample(0.5, seed=1).select(r, 6)
            for r in range(5)] == sels
    assert len({tuple(s) for s in sels}) > 1
    assert Participation().select(3, 4) == [0, 1, 2, 3]
    assert [Participation.cycle(0.5).select(r, 4) for r in range(3)] == \
        [[0, 1], [2, 3], [0, 1]]
    with pytest.raises(ValueError):
        Participation(0.0)
    with pytest.raises(ValueError):
        Participation(0.5, mode="nope")


@pytest.mark.parametrize("method", ["fedadp", "clustered", "flexifed",
                                    "standalone"])
def test_partial_participation_loop(method):
    """fraction < 1 with seeded per-round sampling runs every method on
    the loop backend; callbacks see the per-round subset."""
    cfgs, mk, test = _setup(archs=("vgg13", "vgg13"))
    samplers = mk()
    strategy = make_strategy(method, FAMILY, cfgs,
                             [s.n_samples for s in samplers])
    records = []
    fed = Federation(strategy, _backend("loop", cfgs, samplers), rounds=2,
                     eval_batch=test,
                     participation=Participation.sample(0.5, seed=2),
                     callbacks=[records.append])
    res = fed.run(jax.random.PRNGKey(0))
    assert len(res["history"]) == 2
    assert res["final_acc"] is not None
    assert [len(r["selected"]) for r in records] == [1, 1]


def test_unified_backend_rebind_rebuilds_engine():
    """Rebinding the same method reuses the engine (jitted step kept);
    rebinding a different method must rebuild it, not run stale math."""
    cfgs, mk, _ = _setup()
    samplers = mk()
    n = [s.n_samples for s in samplers]
    backend = UnifiedBackend(FAMILY, cfgs, samplers, local_epochs=1)
    e1 = backend.bind(FedADPStrategy(FAMILY, cfgs, n)).engine
    assert backend.bind(FedADPStrategy(FAMILY, cfgs, n)).engine is e1
    e2 = backend.bind(make_strategy("clustered", FAMILY, cfgs, n)).engine
    assert e2 is not e1 and e2.method == "clustered"


def _tiny_vgg(name, stages):
    from repro.configs.vgg_family import VGGConfig
    return VGGConfig(name=name, stages=stages, classifier=(16,),
                     n_classes=4, image_size=8)


def _tiny_setup():
    """A 3-client depth-heterogeneous VGG cohort small enough to jit the
    whole method x participation matrix on the CPU CI box."""
    import dataclasses
    cfgs = [_tiny_vgg("t2", ((8,), (8,))), _tiny_vgg("t3", ((8,), (8, 8))),
            _tiny_vgg("t4", ((8, 8), (8, 8)))]
    spec = dataclasses.replace(EASY, image_size=8, n_classes=4)
    data = image_classification(spec, 96, seed=0)
    test = image_classification(spec, 48, seed=9)
    parts = iid_partition(96, len(cfgs), seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i) for i, p in enumerate(parts)]

    return cfgs, samplers, test


def test_unified_matches_loop_per_method_and_participation():
    """The acceptance matrix: every method (fedadp zero / global /
    coverage-aggregated, clustered, flexifed, standalone) x participation
    (full, sample, cycle) runs on the UnifiedBackend and matches the
    LoopBackend to 1e-5 on a depth-heterogeneous VGG cohort — both
    backends consume identical per-round data, non-participants' sampler
    streams do not advance, and coverage semantics are single-sourced in
    core.aggregation."""
    from repro.models import vgg as V
    cfgs, mk, test = _tiny_setup()
    assert FAMILY.depth_only(cfgs)
    gcfg = FAMILY.union(cfgs)
    loopb = LoopBackend(FAMILY, cfgs, mk(), local_epochs=1, lr=0.05,
                        momentum=0.9)
    unib = UnifiedBackend(FAMILY, cfgs, mk(), local_epochs=1, lr=0.05,
                          momentum=0.9)

    def run(backend, method, participation, **kw):
        backend.samplers = mk()          # fresh identical streams per run
        strategy = make_strategy(method, FAMILY, cfgs,
                                 [s.n_samples for s in backend.samplers],
                                 **kw)
        fed = Federation(strategy, backend, rounds=2, eval_batch=test,
                         participation=participation)
        return fed.run(jax.random.PRNGKey(0))

    matrix = [("fedadp", {}), ("fedadp", dict(filler="global")),
              ("fedadp", dict(agg_mode="coverage")),
              ("clustered", {}), ("flexifed", {}), ("standalone", {})]
    participations = [("full", Participation()),
                      ("sample", Participation.sample(0.6, seed=2)),
                      ("cycle", Participation.cycle(0.6))]
    for method, kw in matrix:
        for pname, part in participations:
            tag = f"{method}/{kw or 'zero'}/{pname}"
            rl = run(loopb, method, part, **kw)
            ru = run(unib, method, part, **kw)
            np.testing.assert_allclose(rl["history"], ru["history"],
                                       atol=1e-5, err_msg=tag)
            if method == "fedadp":
                for a, b in zip(jax.tree.leaves(rl["global_params"]),
                                jax.tree.leaves(ru["global_params"])):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32), np.asarray(b, np.float32),
                        atol=1e-5, err_msg=tag)
            else:
                # loop params are client-space, engine params are the
                # embedded global-space views: compare client functions
                for k in range(len(cfgs)):
                    la = V.apply(rl["client_params"][k], cfgs[k],
                                 test["x"][:8])
                    lb = V.apply(ru["client_params"][k], gcfg, test["x"][:8])
                    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                               atol=1e-5, err_msg=tag)


def _tiny_width_setup():
    """A 3-client depth+WIDTH heterogeneous VGG cohort (ISSUE 4): the
    unified engine must now be loop-equivalent here too — segment
    operators, per-round embed seeds, multiplicity-aware coverage."""
    import dataclasses
    cfgs = [_tiny_vgg("w1", ((8,), (8,))),
            _tiny_vgg("w2", ((8,), (12, 8))),
            _tiny_vgg("w3", ((12, 8), (12, 8)))]
    spec = dataclasses.replace(EASY, image_size=8, n_classes=4)
    data = image_classification(spec, 96, seed=0)
    test = image_classification(spec, 48, seed=9)
    parts = iid_partition(96, len(cfgs), seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i) for i, p in enumerate(parts)]

    return cfgs, samplers, test


def test_unified_matches_loop_width_vgg_matrix():
    """The width acceptance matrix: every method (fedadp paper / fold /
    global / coverage-aggregated, clustered, flexifed, standalone) x
    participation (full, sample) runs on the UnifiedBackend and matches
    the LoopBackend to 1e-4 on a WIDTH+depth heterogeneous VGG cohort.
    The depth_only gate is deleted: the cohort is unified-eligible even
    though widths differ."""
    from repro.models import vgg as V
    cfgs, mk, test = _tiny_width_setup()
    assert not FAMILY.depth_only(cfgs)
    assert FAMILY.segment_representable(cfgs)
    strat = make_strategy("fedadp", FAMILY, cfgs, [32, 32, 32])
    assert unified_eligible(strat, FAMILY, cfgs, mk())
    assert unified_ineligible_reason(strat, FAMILY, cfgs, mk()) is None
    gcfg = FAMILY.union(cfgs)
    loopb = LoopBackend(FAMILY, cfgs, mk(), local_epochs=1, lr=0.05,
                        momentum=0.9)
    unib = UnifiedBackend(FAMILY, cfgs, mk(), local_epochs=1, lr=0.05,
                          momentum=0.9)

    def run(backend, method, participation, **kw):
        backend.samplers = mk()
        strategy = make_strategy(method, FAMILY, cfgs,
                                 [s.n_samples for s in backend.samplers],
                                 **kw)
        fed = Federation(strategy, backend, rounds=2, eval_batch=test,
                         participation=participation)
        return fed.run(jax.random.PRNGKey(0))

    matrix = [("fedadp", {}), ("fedadp", dict(narrow_mode="fold")),
              ("fedadp", dict(filler="global")),
              ("fedadp", dict(agg_mode="coverage")),
              ("clustered", {}), ("flexifed", {}), ("standalone", {})]
    participations = [("full", Participation()),
                      ("sample", Participation.sample(0.6, seed=2))]
    for method, kw in matrix:
        for pname, part in participations:
            tag = f"width/{method}/{kw or 'zero'}/{pname}"
            rl = run(loopb, method, part, **kw)
            ru = run(unib, method, part, **kw)
            np.testing.assert_allclose(rl["history"], ru["history"],
                                       atol=1e-4, err_msg=tag)
            if method == "fedadp":
                for a, b in zip(jax.tree.leaves(rl["global_params"]),
                                jax.tree.leaves(ru["global_params"])):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32), np.asarray(b, np.float32),
                        atol=1e-4, err_msg=tag)
            else:
                # loop params are client-space, engine params the embedded
                # union-space views: compare client functions
                for k in range(len(cfgs)):
                    la = V.apply(rl["client_params"][k], cfgs[k],
                                 test["x"][:8])
                    lb = V.apply(ru["client_params"][k], gcfg, test["x"][:8])
                    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                               atol=1e-4, err_msg=tag)


def test_unified_matches_loop_width_transformer_ffn():
    """Width-heterogeneous Transformer-FFN cohort (d_ff + depth differ):
    fedadp loop vs unified to 1e-4 under full and sampled
    participation."""
    from repro.configs import get_config, reduced
    from repro.core import TransformerFamily, tfamily
    from repro.data.synthetic import lm_sequences
    family = TransformerFamily()
    base = reduced(get_config("glm4-9b"), n_units=2, d_model=32)
    cfgs = [tfamily.make_variant(base, n_units=2, ffn_scale=0.5),
            tfamily.make_variant(base, n_units=1, ffn_scale=1.0)]
    assert not family.depth_only(cfgs)
    assert family.segment_representable(cfgs)
    seqs = np.asarray(lm_sequences(base.vocab_size, 48, 16, seed=0))
    data = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
    test = {"tokens": seqs[:8, :-1], "labels": seqs[:8, 1:]}
    parts = iid_partition(48, len(cfgs), seed=0)

    def mk():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i) for i, p in enumerate(parts)]

    strat = make_strategy("fedadp", family, cfgs, [24, 24])
    assert unified_eligible(strat, family, cfgs, mk())
    for pname, part in [("full", Participation()),
                        ("sample", Participation.sample(0.5, seed=3))]:
        out = {}
        for kind, cls in (("loop", LoopBackend), ("unified", UnifiedBackend)):
            b = cls(family, cfgs, mk(), local_epochs=1, lr=0.05, momentum=0.9)
            strategy = make_strategy("fedadp", family, cfgs,
                                     [s.n_samples for s in b.samplers])
            out[kind] = Federation(strategy, b, rounds=2, eval_batch=test,
                                   participation=part).run(
                                       jax.random.PRNGKey(0))
        np.testing.assert_allclose(out["loop"]["history"],
                                   out["unified"]["history"], atol=1e-4,
                                   err_msg=pname)
        for a, b in zip(jax.tree.leaves(out["loop"]["global_params"]),
                        jax.tree.leaves(out["unified"]["global_params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-4,
                                       err_msg=pname)


def test_unified_ineligible_reason_names_the_gate():
    """Every remaining loop-only condition gets a diagnosable reason;
    eligible cohorts (including width-mixed ones) return None."""
    cfgs, mk, _ = _tiny_width_setup()
    strat = make_strategy("fedadp", FAMILY, cfgs, [32, 32, 32])
    assert unified_ineligible_reason(strat, FAMILY, cfgs, mk()) is None

    class OddStrategy:
        name = "median-of-means"
    assert "not a unified-engine method" in unified_ineligible_reason(
        OddStrategy(), FAMILY, cfgs, mk())

    # non-representable: widths diverge where a client is also shallower
    bad = [_tiny_vgg("n1", ((16,),)), _tiny_vgg("n2", ((16, 8),))]
    assert not FAMILY.segment_representable(bad)
    assert "segment-representable" in unified_ineligible_reason(
        make_strategy("fedadp", FAMILY, bad, [1, 1]), FAMILY, bad, mk()[:2])

    ragged = mk()
    ragged[0].batch_size = 4
    assert "batch sizes" in unified_ineligible_reason(strat, FAMILY, cfgs,
                                                      ragged)
    frac = mk()
    frac[1].round_fraction = 0.25
    assert "fractions" in unified_ineligible_reason(strat, FAMILY, cfgs, frac)


def test_simulator_auto_logs_fallback_reason_once(caplog):
    """engine="auto" falling back to the loop is no longer silent: the
    Simulator logs the ineligibility reason exactly once."""
    cfgs, mk, test = _setup(archs=("vgg13", "vgg13"))
    samplers = mk()
    samplers[0].batch_size = 4            # ragged: keeps the loop
    rc = FLRunConfig(method="standalone", rounds=0, local_epochs=1)
    sim = Simulator(FAMILY, cfgs, samplers, rc, test)
    with caplog.at_level("INFO", logger="repro.fl"):
        assert sim._resolve_engine() == "loop"
        assert sim._resolve_engine() == "loop"
    msgs = [r.getMessage() for r in caplog.records
            if "falls back" in r.getMessage()]
    assert len(msgs) == 1
    assert "batch sizes" in msgs[0]


# ----------------------------------------------------------- config/shim
def test_flrunconfig_eager_validation():
    for kw in (dict(method="fedsgd"), dict(filler="none"),
               dict(narrow_mode="widen"), dict(engine="gpu"),
               dict(coverage="fuzzy"), dict(agg_mode="median"),
               dict(participation=1.5), dict(participation=0.0),
               dict(eval_every=0), dict(rounds=-1), dict(local_epochs=0),
               dict(embed_seed="7"), dict(embed_seed=1.5),
               dict(embed_seed=True)):
        with pytest.raises(ValueError):
            FLRunConfig(**kw)
    # embed_seed follows `seed` unless set explicitly
    assert FLRunConfig(seed=3).resolved_embed_seed == 3
    assert FLRunConfig(seed=3, embed_seed=11).resolved_embed_seed == 11


def test_simulator_cfg_mutation_takes_effect():
    """benchmarks/unified_bench.py warms up with rounds=1 then swaps
    sim.cfg for the timed run — the Federation must be rebuilt per run
    (jit caches live in the backend and stay warm)."""
    import dataclasses
    cfgs, mk, test = _setup(archs=("vgg13",))
    rc = FLRunConfig(method="standalone", rounds=1, local_epochs=1, lr=0.05)
    sim = Simulator(FAMILY, cfgs, mk(), rc, test)
    assert len(sim.run()["history"]) == 1
    sim.cfg = dataclasses.replace(rc, rounds=3)
    assert len(sim.run()["history"]) == 3
    assert len(sim._backends) == 1         # backend (and its jits) reused


def test_shared_backend_rebinds_per_run():
    """Two Federations over one backend: each run() re-binds its own
    strategy, so constructing the second must not hijack the first."""
    cfgs, mk, test = _setup(archs=("vgg13", "vgg13"))
    backend = _backend("loop", cfgs, mk())
    n = [s.n_samples for s in backend.samplers]
    fed_a = Federation(FedADPStrategy(FAMILY, cfgs, n), backend, rounds=1,
                       eval_batch=test)
    fed_b = Federation(make_strategy("standalone", FAMILY, cfgs, n), backend,
                       rounds=1, eval_batch=test)
    res_a = fed_a.run(jax.random.PRNGKey(0))     # after fed_b bound itself
    assert res_a["global_params"] is not None    # fedadp ran, not standalone
    res_b = fed_b.run(jax.random.PRNGKey(0))
    assert res_b["global_params"] is None


def test_final_acc_populated_when_eval_every_exceeds_rounds():
    cfgs, mk, test = _setup(archs=("vgg13",))
    rc = FLRunConfig(method="standalone", rounds=1, local_epochs=1,
                     eval_every=5)
    res = Simulator(FAMILY, cfgs, mk(), rc, test).run()
    assert res["history"] == []
    assert res["final_acc"] is not None
    assert 0.0 <= res["final_acc"] <= 1.0
