"""Segment operators (core/segments.py; ISSUE 4): the width embedding
as an explicit linear map.

Property-style via seeded parametrized loops (no ``hypothesis`` on this
box):
  * ``up()`` is affine and its linear part's pushforward-pullback
    ``E Eᵀ`` equals the family's ``segment_spec`` gradient operator —
    checked against jax autodiff of ``up`` itself (vjp then jvp),
  * ``down(up(p))`` with ``narrow_mode="fold"`` is exact on width moves,
  * the segment-mean projection is idempotent, commutes with the 0/1
    mask projection, and equals ``up(down_fold(·))`` on covered
    coordinates,
  * multiplicity trees count To-Wider duplication exactly,
  * the loop path builds coverage masks once per distinct embedding seed
    (the shared ``netchange.KeyedCache``, keyed on the per-round seed;
    ``cache_stats()`` exposes its counters).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.vgg_family import VGGConfig
from repro.core import (FedADP, TransformerFamily, VGGFamily,
                        coverage_and_filler, multiplicity, tfamily)
from repro.core import segments as sg


def _tiny(name, stages, classifier=(10,)):
    return VGGConfig(name=name, stages=stages, classifier=classifier,
                     n_classes=4, image_size=8)


def _vgg_width_pair():
    fam = VGGFamily()
    cfgs = [_tiny("a", ((6,), (8, 8)), classifier=(10,)),
            _tiny("b", ((6, 6), (12, 8)), classifier=(16,))]
    return fam, cfgs, fam.union(cfgs)


def _tfm_width_pair():
    fam = TransformerFamily()
    base = reduced(get_config("glm4-9b"), n_units=2, d_model=32)
    cfgs = [tfamily.make_variant(base, n_units=2, ffn_scale=0.5),
            tfamily.make_variant(base, n_units=1, ffn_scale=1.0)]
    return fam, cfgs, fam.union(cfgs)


def _tfm_rnn_pair():
    """RG-LRU d_rnn width — loop-only today (segment_representable is
    False), but its embedding is still linear and the spec must describe
    it exactly (loop-side multiplicity in coverage aggregation)."""
    fam = TransformerFamily()
    base = reduced(get_config("recurrentgemma-9b"), n_units=1, d_model=32)
    cfgs = [tfamily.make_variant(base, d_rnn=base.d_rnn // 2),
            tfamily.make_variant(base)]
    return fam, cfgs, fam.union(cfgs)


def _tfm_moe_pair():
    """MoE expert width d_ff_expert (+ d_ff) — ditto: linear, loop-only."""
    fam = TransformerFamily()
    base = reduced(get_config("mixtral-8x7b"), n_units=1, d_model=32)
    cfgs = [tfamily.make_variant(base, ffn_scale=0.5),
            tfamily.make_variant(base)]
    return fam, cfgs, fam.union(cfgs)


def _rand_like(shapes, seed):
    key = jax.random.PRNGKey(seed)
    leaves, treedef = jax.tree.flatten(shapes)
    out = [jax.random.normal(jax.random.fold_in(key, i), s.shape)
           .astype(s.dtype) for i, s in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def _max_diff(a, b, weight=None):
    ws = (jax.tree.leaves(weight) if weight is not None
          else [1.0] * len(jax.tree.leaves(a)))
    return max(float(jnp.abs((x - y) * w).max()) for x, y, w in
               zip(jax.tree.leaves(a), jax.tree.leaves(b), ws))


@pytest.mark.parametrize("maker", [_vgg_width_pair, _tfm_width_pair,
                                   _tfm_rnn_pair, _tfm_moe_pair],
                         ids=["vgg", "tffn", "trnn", "tmoe"])
@pytest.mark.parametrize("seed", [0, 11])
def test_grad_operator_matches_autodiff_of_up(maker, seed):
    """``segment_spec``'s E Eᵀ (with the 0/1 mask handling depth) IS the
    pushforward of the client-shape gradient: for any union cotangent g,
    ``jvp(up)(vjp(up)(g))`` equals mask ∘ segment-project(g). This is the
    exact condition under which stacked union-space SGD equals the
    per-client loop."""
    fam, cfgs, gcfg = maker()
    for cfg in cfgs:
        spec = fam.segment_spec(cfg, gcfg, seed=seed)
        p = fam.init(jax.random.PRNGKey(1), cfg)

        def up(q):
            return fam.up(q, cfg, gcfg, seed=seed)

        gshapes = jax.eval_shape(lambda k: fam.init(k, gcfg),
                                 jax.random.PRNGKey(0))
        g = _rand_like(gshapes, 7 + seed)
        _, vjp = jax.vjp(up, p)
        (pbar,) = vjp(g)
        _, eet = jax.jvp(up, (p,), (pbar,))
        mask, _ = coverage_and_filler(fam, cfg, gcfg, seed=seed)
        got = jax.tree.map(lambda x, m: x * m,
                           sg.project_client(g, spec, kind="grad"), mask)
        want = jax.tree.map(lambda x, m: x * m, eet, mask)
        assert _max_diff(want, got) < 1e-5


@pytest.mark.parametrize("maker", [_vgg_width_pair, _tfm_width_pair,
                                   _tfm_rnn_pair, _tfm_moe_pair],
                         ids=["vgg", "tffn", "trnn", "tmoe"])
@pytest.mark.parametrize("seed", [0, 3])
def test_down_up_fold_roundtrip_exact_on_width(maker, seed):
    """``down(up(p), mode="fold")`` with the same seed recovers the
    client tree exactly: fold is the left inverse of the width
    embedding (mean over duplicated copies, sum over split copies)."""
    fam, cfgs, gcfg = maker()
    for cfg in cfgs:
        p = fam.init(jax.random.fold_in(jax.random.PRNGKey(2), seed), cfg)
        back = fam.down(fam.up(p, cfg, gcfg, seed=seed), gcfg, cfg,
                        seed=seed, mode="fold")
        assert _max_diff(p, back) < 1e-6


@pytest.mark.parametrize("seed", [0, 5])
def test_segment_mean_projection_idempotent_and_commutes(seed):
    """The mean projector P = E (EᵀE)⁻¹ Eᵀ is idempotent (P P = P),
    commutes with the 0/1 mask projection (masks are constant along
    segment axes), and equals ``up(down_fold(·))`` on strictly covered
    coordinates."""
    fam, cfgs, gcfg = _vgg_width_pair()
    gshapes = jax.eval_shape(lambda k: fam.init(k, gcfg),
                             jax.random.PRNGKey(0))
    u = _rand_like(gshapes, 31 + seed)
    for cfg in cfgs:
        spec = fam.segment_spec(cfg, gcfg, seed=seed)
        mask, _ = coverage_and_filler(fam, cfg, gcfg, seed=seed)
        p1 = sg.project_client(u, spec, kind="mean")
        p2 = sg.project_client(p1, spec, kind="mean")
        assert _max_diff(p1, p2) < 1e-5
        a = jax.tree.map(lambda x, m: x * m, p1, mask)
        b = sg.project_client(jax.tree.map(lambda x, m: x * m, u, mask),
                              spec, kind="mean")
        assert _max_diff(a, b) < 1e-5
        ud = fam.up(fam.down(u, gcfg, cfg, seed=seed, mode="fold"),
                    cfg, gcfg, seed=seed)
        assert _max_diff(ud, p1, weight=mask) < 1e-5


def test_multiplicity_counts_duplication():
    """Multiplicity = per-coordinate duplication counts: ones on leaves
    the embedding never widens, per-segment group sizes on widened axes
    (summing the inverse over a segment gives exactly 1 client channel),
    and all-ones for a depth-only embedding."""
    fam, cfgs, gcfg = _vgg_width_pair()
    cfg = cfgs[0]                        # widened client
    mult = multiplicity(fam, cfg, gcfg, seed=4)
    spec = fam.segment_spec(cfg, gcfg, seed=4)
    # every count is a positive integer >= 1
    for leaf in jax.tree.leaves(mult):
        arr = np.asarray(leaf)
        assert np.all(arr >= 1) and np.allclose(arr, np.round(arr))
    # on a widened conv's output axis the counts are the segment sizes:
    # sum over union channels of 1/mult recovers the client channel count
    path = ("stages", "s1", "c0", "b")
    assert path in spec and not spec[path][0].out_role
    counts = spec[path][0].counts
    b_mult = np.asarray(mult["stages"]["s1"]["c0"]["b"])
    np.testing.assert_array_equal(b_mult, counts)
    assert float(np.sum(1.0 / b_mult)) == pytest.approx(
        cfg.stages[1][0], abs=1e-6)
    # depth-only: all ones
    deep = [_tiny("d1", ((6,), (8,)), classifier=(10,)),
            _tiny("d2", ((6,), (8, 8)), classifier=(10,))]
    g2 = fam.union(deep)
    m2 = multiplicity(fam, deep[0], g2, seed=9)
    assert all(float(x.min()) == 1.0 and float(x.max()) == 1.0
               for x in jax.tree.leaves(m2))


def test_loop_mask_cache_one_build_per_distinct_seed(monkeypatch):
    """Width-heterogeneous cohorts no longer rebuild coverage masks
    every round: the mask entries of ``FedADP``'s ``KeyedCache`` key on
    the per-round embedding seed, so repeated lookups of the same
    (round, client) hit the cache and a new round triggers exactly one
    build per client — visible in ``cache_stats()``."""
    import repro.core.fedadp as fmod
    fam, cfgs, gcfg = _vgg_width_pair()
    algo = FedADP(fam, cfgs, [1, 1], agg_mode="coverage")
    assert not algo._depth_only
    calls = []
    real = fmod.coverage_mask

    def counting(*a, **kw):
        calls.append(kw.get("seed"))
        return real(*a, **kw)

    monkeypatch.setattr(fmod, "coverage_mask", counting)
    for _ in range(3):                       # same round, repeated lookups
        algo.coverage_mask(0, 0)
        algo.coverage_mask(0, 1)
    assert len(calls) == 2                   # one build per distinct seed
    stats = algo.cache_stats()
    assert stats["misses"] == 2 and stats["hits"] == 4
    algo.coverage_mask(1, 0)                 # new round = new seed
    algo.coverage_mask(1, 0)
    assert len(calls) == 3
    assert len(set(calls)) == 3
    assert algo.cache_stats()["misses"] == 3
    # depth-only cohorts collapse every seed to one entry per (k, policy)
    deep = [_tiny("d1", ((6,), (8,))), _tiny("d2", ((6,), (8, 8)))]
    algo2 = FedADP(fam, deep, [1, 1])
    calls.clear()
    algo2.coverage_mask(0, 0)
    algo2.coverage_mask(5, 0)                # different round, same mask
    assert len(calls) == 1
    assert algo2.cache_stats() == {"hits": 1, "misses": 1, "size": 1,
                                   "bound": max(128, 4 * len(deep))}


def test_mask_cache_is_bounded():
    """The seed-keyed cache must not grow without bound over a long
    run — ``netchange.KeyedCache`` is an LRU capped at max(128, 4·K),
    the ONE sizing rule the loop and engine caches share."""
    fam, cfgs, _ = _vgg_width_pair()
    algo = FedADP(fam, cfgs, [1, 1])
    cap = max(128, 4 * len(cfgs))
    for r in range(cap + 7):
        algo.coverage_mask(r, 0)
    stats = algo.cache_stats()
    assert stats["size"] <= cap and stats["bound"] == cap
    assert len(algo._cache) <= cap


def test_stacked_project_matches_per_client():
    """``project_stacked`` (the engine's in-step form, identity-padded
    matrices stacked over K) == per-client ``project_client``."""
    fam, cfgs, gcfg = _vgg_width_pair()
    gshapes = jax.eval_shape(lambda k: fam.init(k, gcfg),
                             jax.random.PRNGKey(0))
    specs = [fam.segment_spec(c, gcfg, seed=2) for c in cfgs]
    axes_map = sg.union_axes(specs, gshapes)
    mats = [sg.client_matrices(s, axes_map, gshapes, kind="grad")
            for s in specs]
    stacked_mats = sg.stack_matrices(mats)
    axes_str = {"/".join(p): a for p, a in axes_map.items()}
    gs = [_rand_like(gshapes, 40 + i) for i in range(len(cfgs))]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *gs)
    got = sg.project_stacked(stacked, axes_str, stacked_mats)
    for k, (g, spec) in enumerate(zip(gs, specs)):
        want = sg.project_client(g, spec, kind="grad")
        gk = jax.tree.map(lambda x: x[k], got)
        assert _max_diff(want, gk) < 1e-5
