"""Blockwise attention vs naive reference; banded == full; decode caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (blockwise_attention, decode_attention,
                                    ring_positions)

KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite


def naive_attention(q, k, v, causal=True, window=0):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k) * hd ** -0.5
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (pos[:, None] >= pos[None, :])
    if window > 0:
        mask = mask & (pos[:, None] - pos[None, :] < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p, v)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


@pytest.mark.parametrize("S,H,KV,window,bq,bk",
                         [(48, 4, 2, 0, 16, 16), (65, 4, 1, 0, 16, 32),
                          (64, 2, 2, 24, 16, 16), (100, 4, 4, 17, 32, 16)])
def test_blockwise_matches_naive(S, H, KV, window, bq, bk):
    hd = 16
    q = jax.random.normal(KEY, (2, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, KV, hd))
    pos = jnp.arange(S)
    want = naive_attention(q, k, v, causal=True, window=window)
    q5 = q.reshape(2, S, KV, H // KV, hd)
    for banded in ([False, True] if window else [False]):
        got = blockwise_attention(q5, k, v, pos, pos, causal=True,
                                  window=window, block_q=bq, block_kv=bk,
                                  banded=banded)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"banded={banded}")


def test_causal_skip_matches_full():
    S, H, KV, hd = 64, 2, 2, 16
    q = jax.random.normal(KEY, (1, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (1, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (1, S, KV, hd))
    pos = jnp.arange(S)
    q5 = q.reshape(1, S, KV, H // KV, hd)
    a = blockwise_attention(q5, k, v, pos, pos, causal=True, window=0,
                            block_q=16, block_kv=16, banded=False,
                            causal_skip=False)
    b = blockwise_attention(q5, k, v, pos, pos, causal=True, window=0,
                            block_q=16, block_kv=16, banded=False,
                            causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_blockwise_is_differentiable():
    S, H, hd = 32, 2, 8
    q = jax.random.normal(KEY, (1, S, H, hd))
    pos = jnp.arange(S)

    def f(q):
        q5 = q.reshape(1, S, H, 1, hd)
        return blockwise_attention(q5, q, q, pos, pos, causal=True, window=8,
                                   block_q=16, block_kv=16, banded=True).sum()

    g = jax.grad(f)(q)
    assert np.isfinite(np.asarray(g)).all()


@given(pos=st.integers(0, 300), W=st.sampled_from([16, 64, 128]))
@settings(max_examples=20, deadline=None)
def test_ring_positions_invariants(pos, W):
    kp = np.asarray(ring_positions(jnp.int32(pos), W))
    # every held position is in (pos - W, pos] and lives in its slot
    held = kp[kp >= 0]
    assert (held > pos - W).all() and (held <= pos).all()
    slots = np.where(kp >= 0)[0]
    assert ((held % W) == slots).all()
    # exactly min(pos+1, W) positions held
    assert len(held) == min(pos + 1, W)


def test_decode_attention_matches_naive_last_row():
    S, H, KV, hd = 40, 4, 2, 16
    q = jax.random.normal(KEY, (2, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (2, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (2, S, KV, hd))
    want = naive_attention(q, k, v, causal=True)[:, -1]
    got = decode_attention(q[:, -1], k, v, jnp.arange(S), jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
