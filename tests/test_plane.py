"""Packed parameter plane (core/plane.py; ISSUE 5).

Property-style via seeded parametrized loops (no ``hypothesis`` on this
box):
  * pack/unpack round-trips bit-exactly across the vgg / transformer-FFN
    / RG-LRU / MoE union architectures and across dtypes (a bf16 leaf
    rides the f32 plane exactly: accumulate in f32, cast back),
  * ragged input raises ``ValueError`` naming the offending leaf path
    and the two mismatched shapes — the one message contract shared by
    ``stack_trees`` and ``PlaneSpec``,
  * the packed-plane aggregation path equals the per-leaf reference
    dispatch to 1e-6 across masks × mult × fallback × renorm ×
    use_kernel (``fedavg_stacked(layout="plane"|"leaf")``), and the
    fused whole-plane kernel equals its jnp oracle,
  * ``checkpoint.save_plane``/``load_plane`` round-trip bit-exactly,
  * the engine's one ``KeyedCache`` exposes hit/miss stats and shares
    the loop's sizing bound.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.vgg_family import VGGConfig
from repro.core import (FedADP, TransformerFamily, VGGFamily, client_weights,
                        fedavg, fedavg_stacked, stack_trees, tfamily)
from repro.core import plane as pl
from repro.core.aggregation import global_shapes
from repro.checkpoint import load_plane, save_plane
from repro.kernels.fedavg import ops as kops, ref as kref


def _tiny(name, stages, classifier=(10,)):
    return VGGConfig(name=name, stages=stages, classifier=classifier,
                     n_classes=4, image_size=8)


def _families():
    vgg_fam = VGGFamily()
    vgg_cfgs = [_tiny("a", ((6,), (8, 8))),
                _tiny("b", ((6, 6), (12, 8)), classifier=(16,))]
    tf = TransformerFamily()
    ffn = reduced(get_config("glm4-9b"), n_units=2, d_model=32)
    rnn = reduced(get_config("recurrentgemma-9b"), n_units=1, d_model=32)
    moe = reduced(get_config("mixtral-8x7b"), n_units=1, d_model=32)
    return {
        "vgg": (vgg_fam, vgg_fam.union(vgg_cfgs)),
        "tffn": (tf, tf.union([tfamily.make_variant(ffn, ffn_scale=0.5),
                               tfamily.make_variant(ffn)])),
        "trnn": (tf, tf.union([tfamily.make_variant(rnn,
                                                    d_rnn=rnn.d_rnn // 2),
                               tfamily.make_variant(rnn)])),
        "tmoe": (tf, tf.union([tfamily.make_variant(moe, ffn_scale=0.5),
                               tfamily.make_variant(moe)])),
    }


def _rand_like(shapes, seed):
    key = jax.random.PRNGKey(seed)
    leaves, treedef = jax.tree.flatten(shapes)
    out = [jax.random.normal(jax.random.fold_in(key, i), s.shape)
           .astype(s.dtype) for i, s in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------------------ round-trips
@pytest.mark.parametrize("fkey", ["vgg", "tffn", "trnn", "tmoe"])
def test_pack_unpack_roundtrip_families(fkey):
    """Union architectures of every family round-trip bit-exactly (the
    plane is f32; every leaf dtype here embeds exactly)."""
    fam, gcfg = _families()[fkey]
    shapes = global_shapes(fam, gcfg)
    spec = pl.PlaneSpec.from_tree(shapes)
    assert spec.size == sum(spec.leaf_sizes())
    for seed in (0, 1, 2):
        tree = _rand_like(shapes, seed)
        back = pl.unpack(pl.pack(tree, spec), spec)
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(tree),
                jax.tree_util.tree_leaves_with_path(back)):
            assert a.dtype == b.dtype, path
            assert np.array_equal(np.asarray(a), np.asarray(b)), path


@pytest.mark.parametrize("k", [1, 3])
def test_pack_stacked_roundtrip_and_spec(k):
    fam, gcfg = _families()["vgg"]
    shapes = global_shapes(fam, gcfg)
    stacked = stack_trees([_rand_like(shapes, 10 + i) for i in range(k)])
    spec, kk = pl.PlaneSpec.from_stacked(stacked)
    assert kk == k and spec == pl.PlaneSpec.from_tree(shapes)
    sp = pl.pack_stacked(stacked, spec)
    assert sp.shape == (k, spec.size) and sp.dtype == jnp.float32
    back = pl.unpack_stacked(sp, spec)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_bf16_leaf_rides_f32_plane_exactly():
    """A bf16 leaf accumulates in f32 on the plane and casts back
    bit-exactly (every bf16 value is exactly representable in f32);
    ``requantize`` rounds plane columns through the storage dtype and is
    a static no-op on all-f32 specs."""
    tree = {"w": (jnp.arange(12, dtype=jnp.bfloat16) / 3).reshape(3, 4),
            "b": jnp.linspace(-1, 1, 5, dtype=jnp.float32),
            "i": jnp.arange(4, dtype=jnp.float32)}
    spec = pl.PlaneSpec.from_tree(tree)
    assert not spec.all_f32
    sp = pl.pack(tree, spec)
    assert sp.dtype == jnp.float32
    back = pl.unpack(sp, spec)
    assert back["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(back["w"], np.float32),
                          np.asarray(tree["w"], np.float32))
    assert np.array_equal(np.asarray(back["b"]), np.asarray(tree["b"]))
    # requantize: bf16 columns snap to the bf16 grid, f32 columns untouched
    shifted = sp + 1e-4
    rq = pl.requantize(shifted, spec)
    w_cols = slice(spec.offsets[spec.paths.index(("w",))],
                   spec.offsets[spec.paths.index(("w",))] + 12)
    np.testing.assert_array_equal(
        np.asarray(rq[w_cols]),
        np.asarray(shifted[w_cols].astype(jnp.bfloat16), np.float32))
    f32_spec = pl.PlaneSpec.from_tree({"b": tree["b"]})
    f32_plane = pl.pack({"b": tree["b"]}, f32_spec)
    assert pl.requantize(f32_plane, f32_spec) is f32_plane


# ---------------------------------------------------------- ragged errors
def test_ragged_errors_name_leaf_and_shapes():
    """ONE message contract: the offending leaf path and the two shapes,
    raised by stack_trees, PlaneSpec.from_stacked and pack alike."""
    a = {"conv": jnp.zeros((4, 3)), "fc": {"w": jnp.zeros((2, 2))}}
    b = {"conv": jnp.zeros((4, 3)), "fc": {"w": jnp.zeros((2, 5))}}
    with pytest.raises(ValueError, match=r"fc/w.*\(2, 5\).*\(2, 2\)"):
        stack_trees([a, b])
    with pytest.raises(ValueError, match="structure"):
        stack_trees([a, {"conv": jnp.zeros((4, 3))}])
    ragged = {"conv": jnp.zeros((2, 4, 3)), "fc": {"w": jnp.zeros((3, 2, 2))}}
    with pytest.raises(ValueError, match=r"fc/w"):
        pl.PlaneSpec.from_stacked(ragged)
    spec = pl.PlaneSpec.from_tree(a)
    with pytest.raises(ValueError, match=r"fc/w.*\(2, 5\).*\(2, 2\)"):
        pl.pack(b, spec)
    with pytest.raises(ValueError, match="structure"):
        pl.pack({"conv": a["conv"], "fc": {"v": a["fc"]["w"]}}, spec)


# ------------------------------------------------- plane == leaf dispatch
def _cov_fixture(seed, K=4, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    shapes = {"w": (7, 13), "b": (5,), "c": (2, 3, 128)}
    stacked = {n: jax.random.normal(jax.random.fold_in(key, i),
                                    (K,) + s).astype(dtype)
               for i, (n, s) in enumerate(shapes.items())}
    masks = {n: (jax.random.uniform(jax.random.fold_in(key, 10 + i),
                                    (K,) + s) > 0.35).astype(jnp.float32)
             for i, (n, s) in enumerate(shapes.items())}
    mult = {n: jnp.where(masks[n] > 0, 1.0 + (
        jax.random.uniform(jax.random.fold_in(key, 20 + i),
                           (K,) + s) > 0.5).astype(jnp.float32), 0.0)
            for i, (n, s) in enumerate(shapes.items())}
    fallback = {n: jax.random.normal(jax.random.fold_in(key, 30 + i),
                                     s).astype(dtype)
                for i, (n, s) in enumerate(shapes.items())}
    w = client_weights(list(range(1, K + 1)))
    return stacked, masks, mult, fallback, w


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("renorm", [True, False])
def test_plane_equals_leaf_dispatch(use_kernel, renorm):
    """The packed one-pass path == the per-leaf reference dispatch to
    1e-6 across plain / masked / multiplicity / fallback aggregation."""
    for seed in (0, 1):
        stacked, masks, mult, fallback, w = _cov_fixture(seed)
        cases = [dict(), dict(masks=masks, renorm=renorm),
                 dict(masks=masks, mult=mult, renorm=renorm),
                 dict(masks=masks, fallback=fallback, renorm=renorm),
                 dict(masks=masks, mult=mult, fallback=fallback,
                      renorm=renorm)]
        for kw in cases:
            a = fedavg_stacked(stacked, w, use_kernel=use_kernel,
                               layout="plane", **kw)
            b = fedavg_stacked(stacked, w, use_kernel=use_kernel,
                               layout="leaf", **kw)
            for (path, la), (_, lb) in zip(
                    jax.tree_util.tree_leaves_with_path(a),
                    jax.tree_util.tree_leaves_with_path(b)):
                np.testing.assert_allclose(
                    np.asarray(la), np.asarray(lb), atol=1e-6,
                    err_msg=f"{path} {sorted(kw)}")


def test_plane_preserves_leaf_dtype():
    stacked, masks, *_ , w = _cov_fixture(3, dtype=jnp.bfloat16)
    out = fedavg_stacked(stacked, w)
    assert all(leaf.dtype == jnp.bfloat16 for leaf in jax.tree.leaves(out))


def test_fedavg_list_routes_through_plane():
    """Paper Eq. 1 has exactly ONE implementation: the list-of-trees API
    equals the stacked plane pass (and the old per-leaf accumulate loop
    is gone)."""
    key = jax.random.PRNGKey(5)
    trees = [{"w": jax.random.normal(jax.random.fold_in(key, i), (6, 5)),
              "b": jax.random.normal(jax.random.fold_in(key, 9 + i), (3,))}
             for i in range(4)]
    w = client_weights([3, 1, 2, 2])
    a = fedavg(trees, w)
    b = fedavg_stacked(stack_trees(trees), w, layout="leaf")
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-6)


@pytest.mark.parametrize("renorm", [True, False])
def test_plane_agg_kernel_matches_ref(renorm):
    """The fused whole-plane kernel (interpret mode on CPU) == the jnp
    oracle to 1e-6, on a lane-odd P (exercises the pad-to-tile path)."""
    key = jax.random.PRNGKey(0)
    K, P = 4, 1000
    x = jax.random.normal(key, (K, P))
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
    m = (jax.random.uniform(jax.random.fold_in(key, 1), (K, P)) > 0.4
         ).astype(jnp.float32)
    mu = jnp.where(m > 0, 2.0, 0.0)
    fb = jax.random.normal(jax.random.fold_in(key, 2), (P,))
    for kw in [dict(), dict(masks=m), dict(masks=m, mult=mu),
               dict(masks=m, fallback=fb),
               dict(masks=m, mult=mu, fallback=fb)]:
        a = kops.plane_agg(x, w, renorm=renorm, use_kernel=True, **kw)
        b = kref.plane_agg_ref(x, w, renorm=renorm, **kw)
        assert a.shape == (P,)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, err_msg=str(sorted(kw)))


# ------------------------------------------------------------- col masks
def test_col_mask_selects_leaf_columns():
    tree = {"s0": {"c0": jnp.zeros((2, 3)), "c1": jnp.zeros((4,))},
            "out": jnp.zeros((5,))}
    spec = pl.PlaneSpec.from_tree(tree)
    cm = spec.col_mask(lambda path: path[0] == "s0")
    assert cm.shape == (spec.size,) and cm.sum() == 10
    back = pl.unpack(jnp.asarray(cm), spec)
    assert float(back["s0"]["c0"].min()) == 1.0
    assert float(back["out"].max()) == 0.0


# ------------------------------------------------------------ checkpoint
def test_save_load_plane_bit_exact(tmp_path):
    """(plane, PlaneSpec) persists bit-exactly — incl. a bf16 leaf whose
    dtype the spec restores on unpack."""
    tree = {"w": (jnp.arange(8, dtype=jnp.bfloat16) / 7).reshape(2, 4),
            "b": jax.random.normal(jax.random.PRNGKey(0), (11,))}
    spec = pl.PlaneSpec.from_tree(tree)
    sp = pl.pack(tree, spec)
    path = str(tmp_path / "plane.npz")
    save_plane(path, sp, spec, extra={"round": 7})
    arr, spec2, extra = load_plane(path)
    assert extra == {"round": 7}
    assert np.array_equal(np.asarray(sp), arr)          # bit-exact
    assert (spec2.paths, spec2.shapes, spec2.dtypes, spec2.offsets) == \
        (spec.paths, spec.shapes, spec.dtypes, spec.offsets)
    back = pl.unpack(jnp.asarray(arr), spec2)
    assert back["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(back["w"], np.float32),
                          np.asarray(tree["w"], np.float32))
    assert np.array_equal(np.asarray(back["b"]), np.asarray(tree["b"]))


def test_stacked_plane_checkpoint_roundtrip(tmp_path):
    fam, gcfg = _families()["vgg"]
    shapes = global_shapes(fam, gcfg)
    spec = pl.PlaneSpec.from_tree(shapes)
    stacked = stack_trees([_rand_like(shapes, i) for i in range(3)])
    sp = pl.pack_stacked(stacked, spec)
    path = str(tmp_path / "cohort.npz")
    save_plane(path, sp, spec)
    arr, spec2, _ = load_plane(path)
    assert np.array_equal(np.asarray(sp), arr)
    back = pl.unpack_stacked(jnp.asarray(arr), spec2)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- manifest round-trip
def test_manifest_roundtrip_json_and_equality():
    """``to_manifest`` -> json -> ``from_manifest`` rebuilds the SAME
    spec (paths, shapes, dtypes, offsets, size — and the treedef, since
    models here are plain dict pytrees), including non-f32 leaves."""
    import json
    tree = {"enc": {"w": jnp.zeros((3, 4), jnp.bfloat16),
                    "b": jnp.zeros((4,), jnp.float32)},
            "head": jnp.zeros((4, 2), jnp.float32)}
    spec = pl.PlaneSpec.from_tree(tree)
    man = json.loads(json.dumps(spec.to_manifest()))
    spec2 = pl.PlaneSpec.from_manifest(man)
    assert spec2 == spec
    # the rebuilt spec round-trips real data bit-exactly
    sp = pl.pack(tree, spec)
    back = pl.unpack(sp, spec2)
    assert back["enc"]["w"].dtype == jnp.bfloat16
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_manifest_roundtrip_union_architecture():
    fam, gcfg = _families()["tmoe"]
    spec = pl.PlaneSpec.from_tree(global_shapes(fam, gcfg))
    spec2 = pl.PlaneSpec.from_manifest(spec.to_manifest())
    assert (spec2.paths, spec2.shapes, spec2.dtypes, spec2.offsets,
            spec2.size) == (spec.paths, spec.shapes, spec.dtypes,
                            spec.offsets, spec.size)


# --------------------------------------------------- validate error paths
def test_validate_ragged_leaf_names_path_and_shapes():
    spec = pl.PlaneSpec.from_tree({"a": jnp.zeros((2, 3)),
                                   "b": {"w": jnp.zeros((4,))}})
    with pytest.raises(ValueError, match=r"b/w.*\(5,\).*\(4,\)"):
        spec.validate({"a": jnp.zeros((2, 3)), "b": {"w": jnp.zeros((5,))}},
                      what="load")
    with pytest.raises(ValueError, match="leaves"):
        spec.validate({"a": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match="structure"):
        spec.validate({"a": jnp.zeros((2, 3)), "c": {"w": jnp.zeros((4,))}})


def test_validate_stacked_vs_unstacked():
    tree = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((4,))}
    spec = pl.PlaneSpec.from_tree(tree)
    stacked = jax.tree.map(lambda x: jnp.stack([x, x, x]), tree)
    spec.validate(stacked, stacked=True)          # (K,)+shape accepted
    spec.validate(tree)                           # exact shape accepted
    with pytest.raises(ValueError, match=r"a.*\(2, 3\)"):
        spec.validate(tree, stacked=True)         # missing the K axis
    with pytest.raises(ValueError, match=r"a.*\(3, 2, 3\)"):
        spec.validate(stacked)                    # unexpected K axis
    ragged = dict(stacked)
    ragged["b"] = jnp.zeros((2, 4))               # K=2 where a has K=3
    spec.validate(ragged, stacked=True)           # per-leaf trailing only...
    with pytest.raises(ValueError, match=r"b.*\(2, 4\)"):
        pl.pack_stacked(ragged, spec)             # ...pack checks K too


def test_validate_dtype_mismatch_opt_in():
    """dtype checking stays opt-in: the engine packs f32 mask planes
    against specs recording bf16 leaves; loaders where storage dtype IS
    the contract pass ``check_dtypes=True``."""
    spec = pl.PlaneSpec.from_tree({"w": jnp.zeros((2, 2), jnp.bfloat16)})
    f32 = {"w": jnp.zeros((2, 2), jnp.float32)}
    spec.validate(f32)                            # default: shapes only
    with pytest.raises(ValueError, match="dtype.*float32.*bfloat16"):
        spec.validate(f32, check_dtypes=True)
    spec.validate({"w": jnp.zeros((2, 2), jnp.bfloat16)}, check_dtypes=True)


# ------------------------------------------------------------ cache stats
def test_engine_cache_stats_and_shared_bound():
    """The engine's embedding artifacts live in ONE KeyedCache with the
    loop's sizing rule; repeated per-round lookups hit instead of
    rebuilding, visible through ``cache_stats()``."""
    from repro.fl.engine import UnifiedEngine
    fam = VGGFamily()
    cfgs = [_tiny("a", ((6,), (8, 8))),
            _tiny("b", ((6, 6), (12, 8)), classifier=(16,))]
    eng = UnifiedEngine(fam, cfgs, [1, 1], method="fedadp",
                        agg_mode="coverage")
    algo = FedADP(fam, cfgs, [1, 1], agg_mode="coverage")
    assert eng.cache_stats()["bound"] == algo.cache_stats()["bound"] \
        == max(128, 4 * len(cfgs))
    before = eng.cache_stats()
    r1 = eng._client_cov_row(0, 123)
    mid = eng.cache_stats()
    assert mid["misses"] > before["misses"]
    r2 = eng._client_cov_row(0, 123)          # same (client, seed): a hit
    after = eng.cache_stats()
    assert after["hits"] == mid["hits"] + 1
    assert after["misses"] == mid["misses"]
    assert r1 is r2
