"""Property tests for the NetChange primitives (paper Alg. 2 / Alg. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import netchange as nc


@given(old=st.integers(1, 40), extra=st.integers(0, 40),
       seed=st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_dup_mapping_properties(old, extra, seed):
    m = nc.dup_mapping(old, old + extra, tag="t", seed=seed)
    assert m.shape == (old + extra,)
    assert (m[:old] == np.arange(old)).all()          # identity prefix
    assert (m >= 0).all() and (m < old).all()
    m2 = nc.dup_mapping(old, old + extra, tag="t", seed=seed)
    assert (m == m2).all()                            # deterministic


@given(rows=st.integers(1, 8), old=st.integers(1, 12), extra=st.integers(0, 12),
       seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_widen_function_preserving(rows, old, extra, seed):
    """x @ W_in @ W_out is invariant under To-Wider (Alg. 2 semantics)."""
    rng = np.random.default_rng(seed)
    w_in = jnp.asarray(rng.standard_normal((rows, old)), jnp.float32)
    w_out = jnp.asarray(rng.standard_normal((old, 3)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, rows)), jnp.float32)
    m = nc.dup_mapping(old, old + extra, tag="w", seed=seed)
    w_in2 = nc.widen_in(w_in, m, axis=-1)
    w_out2 = nc.widen_out(w_out, m, old, axis=0)
    y1 = x @ w_in @ w_out
    y2 = x @ w_in2 @ w_out2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


@given(rows=st.integers(1, 8), old=st.integers(2, 12), extra=st.integers(0, 12),
       seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_fold_inverts_widen(rows, old, extra, seed):
    rng = np.random.default_rng(seed)
    w_in = jnp.asarray(rng.standard_normal((rows, old)), jnp.float32)
    w_out = jnp.asarray(rng.standard_normal((old, 3)), jnp.float32)
    m = nc.dup_mapping(old, old + extra, tag="f", seed=seed)
    wi = nc.widen_in(w_in, m, axis=-1)
    wo = nc.widen_out(w_out, m, old, axis=0)
    np.testing.assert_allclose(np.asarray(nc.narrow_fold_in(wi, m, old, axis=-1)),
                               np.asarray(w_in), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(nc.narrow_fold_out(wo, m, old, axis=0)),
                               np.asarray(w_out), rtol=1e-5, atol=1e-5)


def test_narrow_paper_mass_redistribution():
    """Alg. 3: survivors absorb sum(deleted)/N_tar of outgoing weight."""
    w = jnp.asarray(np.arange(12, dtype=np.float32).reshape(6, 2))
    out = nc.narrow_out_paper(w, 4, axis=0)
    dropped = np.asarray(w[4:]).sum(axis=0)
    expect = np.asarray(w[:4]) + dropped / 4
    np.testing.assert_allclose(np.asarray(out), expect)
    # total outgoing mass preserved
    np.testing.assert_allclose(np.asarray(out).sum(0), np.asarray(w).sum(0))


def test_identity_conv_exact_under_relu():
    from repro.models.vgg import _conv
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 5)))
    p = {"w": nc.identity_conv(5), "b": jnp.zeros((5,))}
    y = jax.nn.relu(_conv(x, p))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_zero_like_output_proj():
    p = {"attn": {"wq": jnp.ones((3, 3)), "wo": jnp.ones((3, 3))},
         "mlp": {"wd": jnp.ones((3, 3)), "wg": jnp.ones((3, 3))}}
    z = nc.zero_like_output_proj(p, ("wo", "wd"))
    assert float(z["attn"]["wo"].sum()) == 0.0
    assert float(z["mlp"]["wd"].sum()) == 0.0
    assert float(z["attn"]["wq"].sum()) == 9.0
