"""Retrace regression (analysis.retrace; ISSUE 7): ``Federation.run``
on the unified backend compiles everything in round 1 and NOTHING after
— across full and sampled participation. The known hazard is the
engine's per-subset-size jit cache (``UnifiedEngine._steps``): a
weak-typed scalar or re-built closure would silently turn one compile
into a compile per round, which no accuracy test can see.
"""
import jax
import numpy as np
import pytest

from repro.analysis.retrace import RetraceDetector
from repro.configs.vgg_family import scaled, vgg
from repro.core import VGGFamily
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import (Federation, FedADPStrategy, Participation,
                      UnifiedBackend)

FAMILY = VGGFamily()


def test_detector_counts_jit_cache_misses():
    """Sanity: a fresh jit compiles once; the cache hit is silent; a new
    input shape is a new compile."""
    @jax.jit
    def f(x):
        return x * 2 + 1

    with RetraceDetector() as det:
        f(np.ones((3,), np.float32)).block_until_ready()
        first = det.compiles
        assert first >= 1
        det.checkpoint()
        f(np.full((3,), 2.0, np.float32)).block_until_ready()   # cache hit
        assert det.since_checkpoint == 0
        f(np.ones((5,), np.float32)).block_until_ready()        # new shape
        assert det.since_checkpoint >= 1
    assert det.events                     # raw names kept for diagnostics


def _setup():
    cfgs = [scaled(vgg(a), 0.125, 32) for a in ("vgg13", "vgg16")]
    n = 160
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, 80, seed=9)
    parts = iid_partition(n, len(cfgs), seed=0)
    samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=16,
                              seed=i) for i, p in enumerate(parts)]
    return cfgs, samplers, test


@pytest.mark.parametrize("pname,participation", [
    ("full", Participation()),
    ("sample", Participation.sample(0.5, seed=2)),
])
def test_federation_compiles_nothing_after_round_one(pname, participation):
    """Rounds >= 2 hit the round-1 jit caches: zero backend_compile
    events after the first round's record is emitted (training step,
    eval step, and every embedding/aggregation helper included).
    Sampled participation keeps the subset SIZE constant, so it must
    not mint new entries in the per-size step cache either."""
    cfgs, samplers, test = _setup()
    backend = UnifiedBackend(FAMILY, cfgs, samplers, local_epochs=1,
                             lr=0.05, momentum=0.9)
    strategy = FedADPStrategy(FAMILY, cfgs,
                              [s.n_samples for s in samplers])
    det = RetraceDetector()
    rounds_seen = []
    traces_after_r1 = {}

    def after_round(rec):
        rounds_seen.append(rec["round"])
        if len(rounds_seen) == 1:
            det.checkpoint()              # everything up to here may compile
            traces_after_r1.update(backend.engine.step_stats()["traces"])

    fed = Federation(strategy, backend, rounds=3, eval_batch=test,
                     eval_every=1, participation=participation,
                     callbacks=[after_round])
    with det:
        res = fed.run(jax.random.PRNGKey(0))

    assert len(res["history"]) == 3
    assert det.compiles > 0, "round 1 must have compiled the step"
    assert det.since_checkpoint == 0, (
        f"{pname}: {det.since_checkpoint} compile(s) AFTER round 1: "
        f"{det.events[det._mark:]}")
    # the per-size step cache stops growing after round 1 (round 1 may
    # hold >1 entry: the sampler's merged tail batch is a second shape)
    stats = backend.engine.step_stats()
    assert stats["traces"] == traces_after_r1, stats
    assert stats["cache_sizes"] == stats["traces"], (
        "jax compiled entries the wrapper never saw", stats)
    sizes = {2} if pname == "full" else {1}
    assert set(stats["subset_sizes"]) == sizes


def test_chunked_streaming_rounds_compile_nothing_after_round_one():
    """ISSUE 8: the streaming layout (``agg_layout="stream"`` with a
    pinned ``k_chunk``) trains and accumulates chunk-by-chunk — every
    chunk after round 1 must hit the SAME per-size jitted step and the
    SAME donated accumulate step. k_chunk=1 divides the K=2 subset, so
    each round runs 2 equal-size chunks; rounds >= 2 compile nothing."""
    cfgs, samplers, test = _setup()
    backend = UnifiedBackend(FAMILY, cfgs, samplers, local_epochs=1,
                             lr=0.05, momentum=0.9, agg_layout="stream",
                             k_chunk=1)
    strategy = FedADPStrategy(FAMILY, cfgs,
                              [s.n_samples for s in samplers])
    det = RetraceDetector()
    rounds_seen = []

    def after_round(rec):
        rounds_seen.append(rec["round"])
        if len(rounds_seen) == 1:
            det.checkpoint()

    fed = Federation(strategy, backend, rounds=3, eval_batch=test,
                     eval_every=1, callbacks=[after_round])
    with det:
        res = fed.run(jax.random.PRNGKey(0))

    assert len(res["history"]) == 3
    assert backend.engine.agg_stats()["layout"] == "stream"
    assert backend.engine.agg_stats()["k_chunk"] == 1
    assert det.compiles > 0, "round 1 must have compiled the step"
    assert det.since_checkpoint == 0, (
        f"{det.since_checkpoint} compile(s) AFTER round 1 on the "
        f"chunked path: {det.events[det._mark:]}")
    # chunking must not mint per-chunk step entries: every chunk is the
    # same size, so ONE subset-size bucket serves all of them
    assert set(backend.engine.step_stats()["subset_sizes"]) == {1}


def test_flash_bf16_rounds_compile_nothing_after_round_one():
    """ISSUE 10: the flash-attention training path (``attn_backend=
    "flash"``) plus mixed precision (``compute_dtype="bf16"``) ride the
    same per-size jitted step — the custom_vjp kernels, the bf16
    param/grad casts, and the bf16-dtype model config are all bound at
    trace time, so rounds >= 2 compile NOTHING new."""
    from repro.configs import get_config, reduced
    from repro.core import TransformerFamily, tfamily

    base = reduced(get_config("glm4-9b"), n_units=2, d_model=64)
    cfgs = [tfamily.make_variant(base, ffn_scale=0.5),
            tfamily.make_variant(base)]
    family = TransformerFamily()
    rng = np.random.default_rng(0)
    toks = rng.integers(0, base.vocab_size, size=(32, 17)).astype(np.int32)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i)
                for i, p in enumerate((np.arange(0, 16),
                                       np.arange(16, 32)))]
    test = {"tokens": toks[:8, :-1], "labels": toks[:8, 1:]}

    backend = UnifiedBackend(family, cfgs, samplers, local_epochs=1,
                             lr=0.05, momentum=0.9, compute_dtype="bf16",
                             attn_backend="flash")
    strategy = FedADPStrategy(family, cfgs,
                              [s.n_samples for s in samplers])
    det = RetraceDetector()
    rounds_seen = []

    def after_round(rec):
        rounds_seen.append(rec["round"])
        if len(rounds_seen) == 1:
            det.checkpoint()

    fed = Federation(strategy, backend, rounds=3, eval_batch=test,
                     eval_every=1, callbacks=[after_round])
    with det:
        res = fed.run(jax.random.PRNGKey(0))

    assert len(res["history"]) == 3
    assert det.compiles > 0, "round 1 must have compiled the step"
    assert det.since_checkpoint == 0, (
        f"{det.since_checkpoint} compile(s) AFTER round 1 on the "
        f"flash+bf16 path: {det.events[det._mark:]}")


def test_compressed_wire_rounds_compile_nothing_after_round_one():
    """ISSUE 9: the int8 wire path adds an encode jit (core.quant via
    ``engine._wire_encode``), a residual gather/scatter, and the fused
    dequantize-accumulate step. All of it must compile in round 1 only:
    the encode jit is keyed on static (fmt, tile), the residual ops are
    shape-stable, and the payload byte accounting is cached — so rounds
    >= 2 on the compressed path compile NOTHING and sync nothing new."""
    cfgs, samplers, test = _setup()
    backend = UnifiedBackend(FAMILY, cfgs, samplers, local_epochs=1,
                             lr=0.05, momentum=0.9, k_chunk=1,
                             wire="int8")
    strategy = FedADPStrategy(FAMILY, cfgs,
                              [s.n_samples for s in samplers])
    det = RetraceDetector()
    rounds_seen = []

    def after_round(rec):
        rounds_seen.append(rec["round"])
        if len(rounds_seen) == 1:
            det.checkpoint()

    fed = Federation(strategy, backend, rounds=3, eval_batch=test,
                     eval_every=1, callbacks=[after_round])
    with det:
        res = fed.run(jax.random.PRNGKey(0))

    assert len(res["history"]) == 3
    assert backend.wire_stats()["wire"] == "int8"
    assert backend.wire_stats()["bytes_per_round"] > 0
    assert det.compiles > 0, "round 1 must have compiled the step"
    assert det.since_checkpoint == 0, (
        f"{det.since_checkpoint} compile(s) AFTER round 1 on the "
        f"compressed wire path: {det.events[det._mark:]}")
