"""FedADP on the VGG family: union, function preservation, baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg_family import (PAPER_COHORT, paper_client_archs,
                                      scaled, union_config, vgg)
from repro.core import vggops
from repro.models import vgg as V

KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite
COHORT = {a: scaled(vgg(a)) for a in PAPER_COHORT}
GLOBAL = union_config(list(COHORT.values()))
X = jax.random.normal(KEY, (3, 32, 32, 3))


def test_union_is_vgg19_wider():
    gw = scaled(vgg("vgg19-wider"))
    assert GLOBAL.stages == gw.stages
    assert GLOBAL.classifier == gw.classifier


def test_paper_cohort_assignment():
    archs = paper_client_archs()
    assert len(archs) == 20
    assert sum(1 for a in archs if a == "vgg19") == 6


@pytest.mark.parametrize("arch", PAPER_COHORT)
def test_up_preserves_function(arch):
    cfg = COHORT[arch]
    p = V.init_params(jax.random.fold_in(KEY, 1), cfg)
    y0 = V.apply(p, cfg, X)
    pg = vggops.up(p, cfg, GLOBAL, seed=5)
    y1 = V.apply(pg, GLOBAL, X)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["vgg13", "vgg16-wider", "vgg19"])
def test_fold_down_inverts_up(arch):
    cfg = COHORT[arch]
    p = V.init_params(jax.random.fold_in(KEY, 2), cfg)
    pg = vggops.up(p, cfg, GLOBAL, seed=9)
    pb = vggops.down(pg, GLOBAL, cfg, seed=9, mode="fold")
    y0 = V.apply(p, cfg, X)
    y2 = V.apply(pb, cfg, X)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["vgg13", "vgg15", "vgg18"])
def test_down_paper_produces_client_shapes(arch):
    cfg = COHORT[arch]
    gp = V.init_params(KEY, GLOBAL)
    cp = vggops.down(gp, GLOBAL, cfg, mode="paper")
    want = jax.tree.map(lambda l: l.shape, V.init_params(KEY, cfg))
    got = jax.tree.map(lambda l: l.shape, cp)
    assert want == got
    # and the narrowed model still runs
    y = V.apply(cp, cfg, X)
    assert y.shape == (3, cfg.n_classes)
    assert not bool(jnp.isnan(y).any())
