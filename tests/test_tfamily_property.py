"""Property tests over random variant lattices (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.core import tfamily

BASE = reduced(get_config("glm4-9b"), n_units=3, d_model=64)
KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite


@given(units=st.lists(st.integers(1, 3), min_size=1, max_size=4),
       scales=st.lists(st.sampled_from([0.25, 0.5, 1.0]), min_size=1,
                       max_size=4))
@settings(max_examples=20, deadline=None)
def test_union_upper_bounds_every_member(units, scales):
    n = min(len(units), len(scales))
    cohort = [tfamily.make_variant(BASE, n_units=u, ffn_scale=s)
              for u, s in zip(units[:n], scales[:n])]
    uni = tfamily.union(cohort)
    for c in cohort:
        assert uni.n_layers >= c.n_layers
        assert uni.d_ff >= c.d_ff
    # idempotence: union with itself changes nothing structural
    uni2 = tfamily.union([uni, uni])
    assert (uni2.n_layers, uni2.d_ff) == (uni.n_layers, uni.d_ff)
    # union is a member-wise max: it equals some member on each coordinate
    assert uni.n_layers in {c.n_layers for c in cohort}
    assert uni.d_ff in {c.d_ff for c in cohort}


@given(u=st.integers(1, 2), s=st.sampled_from([0.25, 0.5]),
       seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_up_then_paper_down_restores_shapes(u, s, seed):
    var = tfamily.make_variant(BASE, n_units=u, ffn_scale=s)
    uni = tfamily.union([var, BASE])
    from repro.models import transformer as T
    p = T.init_params(jax.random.fold_in(KEY, seed), var)
    up = tfamily.up(p, var, uni, seed=seed)
    down = tfamily.down(up, uni, var, seed=seed, mode="paper")
    want = jax.tree.map(lambda l: l.shape, p)
    got = jax.tree.map(lambda l: l.shape, down)
    assert want == got
    for leaf in jax.tree.leaves(down):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
