"""Flash attention (ISSUE 10): the tiled Pallas forward/backward pair
behind ``kernels/flash_attention`` is grad-exact against the
``blockwise_attention`` reference, and the engine-level knobs it enables
(``attn_backend``, ``compute_dtype``) preserve training numerics.

Three layers of evidence:
  * value + gradient parity of ``flash_attention`` (both the jnp
    fallback and the Pallas kernels in interpret mode) vs
    ``blockwise_attention`` across causal / sliding-window / GQA /
    cross-attention / multi-block shapes, f32 to 1e-5 and bf16 inputs
    to 1e-2;
  * a unified-engine round on the tffn width cohort is backend-
    invariant: ``attn_backend="flash"`` matches ``"blockwise"`` to
    1e-5, and ``compute_dtype="bf16"`` tracks the f32 run to 1e-2;
  * the knob validation surface: forced backends/precision reject the
    loop engine, and non-transformer families reject a forced
    ``attn_backend`` with a clear error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.vgg_family import scaled, vgg
from repro.core import TransformerFamily, VGGFamily, tfamily
from repro.data import EASY, ClientSampler, image_classification, \
    iid_partition
from repro.fl import FLRunConfig, Simulator
from repro.kernels.flash_attention import flash_attention
from repro.models.attention import blockwise_attention

# name, (B, Sq, Sk, KV, G, hd), causal, window, (block_q, block_kv)
SHAPES = [
    ("causal", (2, 16, 16, 2, 2, 8), True, 0, (16, 16)),
    ("gqa", (1, 32, 32, 2, 4, 16), True, 0, (32, 32)),
    ("window", (1, 48, 48, 1, 2, 16), True, 8, (16, 16)),
    ("cross", (2, 24, 40, 2, 1, 8), False, 0, (24, 40)),
    ("multiblock_ragged", (1, 40, 40, 1, 1, 8), True, 12, (16, 16)),
]


def _inputs(B, Sq, Sk, KV, G, hd, dtype=jnp.float32, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (B, Sq, KV, G, hd), dtype)
    k = jax.random.normal(kk, (B, Sk, KV, hd), dtype)
    v = jax.random.normal(kv, (B, Sk, KV, hd), dtype)
    return q, k, v, jnp.arange(Sq), jnp.arange(Sk)


def _val_and_grads(fn, q, k, v, cot):
    """Loss = <out, fixed cotangent> so every output coordinate carries
    a distinct gradient signal."""
    def loss(q, k, v):
        return (fn(q, k, v).astype(jnp.float32) * cot).sum()
    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
    return val, grads


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["ref", "pallas-interpret"])
@pytest.mark.parametrize("name,dims,causal,window,blocks", SHAPES)
def test_flash_grads_match_blockwise_f32(name, dims, causal, window,
                                         blocks, use_kernel):
    B, Sq, Sk, KV, G, hd = dims
    bq, bk = blocks
    q, k, v, qp, kp = _inputs(*dims)
    cot = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, KV * G, hd))

    def flash(q, k, v):
        return flash_attention(q, k, v, qp, kp, causal=causal,
                               window=window, block_q=bq, block_kv=bk,
                               use_kernel=use_kernel, interpret=True)

    def block(q, k, v):
        return blockwise_attention(q, k, v, qp, kp, causal=causal,
                                   window=window, block_q=bq, block_kv=bk)

    fv, fg = _val_and_grads(flash, q, k, v, cot)
    bv, bg = _val_and_grads(block, q, k, v, cot)
    np.testing.assert_allclose(fv, bv, atol=1e-4, rtol=1e-5)
    for nm, a, b in zip("qkv", fg, bg):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"{name}: d{nm} mismatch")


@pytest.mark.parametrize("name,dims,causal,window,blocks",
                         [SHAPES[0], SHAPES[2]])
def test_flash_grads_match_blockwise_bf16(name, dims, causal, window,
                                          blocks):
    """bf16 inputs: both backends accumulate in f32, so they agree to
    bf16 resolution (1e-2) — the mixed-precision training contract."""
    B, Sq, Sk, KV, G, hd = dims
    bq, bk = blocks
    q, k, v, qp, kp = _inputs(*dims, dtype=jnp.bfloat16)
    cot = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, KV * G, hd))

    def flash(q, k, v):
        return flash_attention(q, k, v, qp, kp, causal=causal,
                               window=window, block_q=bq, block_kv=bk,
                               use_kernel=True, interpret=True)

    def block(q, k, v):
        return blockwise_attention(q, k, v, qp, kp, causal=causal,
                                   window=window, block_q=bq, block_kv=bk)

    fv, fg = _val_and_grads(flash, q, k, v, cot)
    bv, bg = _val_and_grads(block, q, k, v, cot)
    np.testing.assert_allclose(fv, bv, atol=1e-2, rtol=1e-2)
    for nm, a, b in zip("qkv", fg, bg):
        assert a.dtype == jnp.bfloat16, f"d{nm} cotangent dtype {a.dtype}"
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-2, rtol=1e-2, err_msg=f"{name}: d{nm} mismatch")


def test_flash_masked_tail_grads_are_zero():
    """Positions marked -1 (the pad convention) contribute nothing: key
    gradients on masked positions are exactly zero."""
    B, Sq, Sk, KV, G, hd = 1, 8, 12, 1, 2, 8
    q, k, v, qp, _ = _inputs(B, Sq, Sk, KV, G, hd)
    kp = jnp.where(jnp.arange(Sk) < 9, jnp.arange(Sk), -1)
    cot = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, KV * G, hd))

    def flash(q, k, v):
        return flash_attention(q, k, v, qp, kp, causal=False,
                               block_q=8, block_kv=4,
                               use_kernel=True, interpret=True)

    _, (dq, dk, dv) = _val_and_grads(flash, q, k, v, cot)
    assert np.abs(np.asarray(dk)[:, 9:]).max() == 0.0
    assert np.abs(np.asarray(dv)[:, 9:]).max() == 0.0
    assert np.abs(np.asarray(dq)).max() > 0.0


# ------------------------------------------------ engine-level invariance
def _tffn_run(attn_backend, compute_dtype):
    """Two federated rounds on the tffn width cohort (reduced glm4-9b,
    full-width + half-FFN variants) through the unified engine."""
    base = reduced(get_config("glm4-9b"), n_units=2, d_model=64)
    variants = [tfamily.make_variant(base, ffn_scale=0.5),
                tfamily.make_variant(base)]
    family = TransformerFamily()
    rng = np.random.default_rng(0)
    toks = rng.integers(0, base.vocab_size, size=(32, 17)).astype(np.int32)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    parts = [np.arange(0, 16), np.arange(16, 32)]
    samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i) for i, p in enumerate(parts)]
    test = {"tokens": toks[:8, :-1], "labels": toks[:8, 1:]}
    cfg = FLRunConfig(method="fedadp", rounds=2, local_epochs=1, lr=0.05,
                      momentum=0.9, eval_every=1, engine="unified",
                      attn_backend=attn_backend,
                      compute_dtype=compute_dtype)
    return Simulator(family, variants, samplers, cfg, test).run()


_RUNS = {}


def _run(attn_backend="auto", compute_dtype="f32"):
    key = (attn_backend, compute_dtype)
    if key not in _RUNS:
        _RUNS[key] = _tffn_run(attn_backend, compute_dtype)
    return _RUNS[key]


def _flat_max_diff(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return max(float(np.abs(np.asarray(x, np.float32)
                            - np.asarray(y, np.float32)).max())
               for x, y in zip(la, lb))


def test_engine_flash_matches_blockwise():
    fl = _run(attn_backend="flash")
    bw = _run(attn_backend="blockwise")
    np.testing.assert_allclose(fl["history"], bw["history"], atol=1e-5)
    assert _flat_max_diff(fl["global_params"], bw["global_params"]) <= 1e-5


def test_engine_bf16_tracks_f32():
    bf = _run(compute_dtype="bf16")
    f32 = _run(compute_dtype="f32")
    assert max(abs(a - b) for a, b in
               zip(bf["history"], f32["history"])) <= 1e-2
    assert _flat_max_diff(bf["global_params"], f32["global_params"]) <= 1e-2
    # the plane and the returned global tree stay f32 — only the local
    # step computes in bf16
    for leaf in jax.tree_util.tree_leaves(bf["global_params"]):
        assert leaf.dtype == jnp.float32


# ---------------------------------------------------- validation surface
def test_forced_knobs_reject_loop_engine():
    with pytest.raises(ValueError, match="compute_dtype"):
        FLRunConfig(engine="loop", compute_dtype="bf16")
    with pytest.raises(ValueError, match="attn_backend"):
        FLRunConfig(engine="loop", attn_backend="flash")
    with pytest.raises(ValueError):
        FLRunConfig(compute_dtype="f16")
    with pytest.raises(ValueError):
        FLRunConfig(attn_backend="fused")


def test_forced_attn_backend_rejects_vgg_family():
    cfgs = [scaled(vgg(a), 0.125, 32) for a in ("vgg13", "vgg16")]
    n = 64
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, 16, seed=9)
    parts = iid_partition(n, len(cfgs), seed=0)
    samplers = [ClientSampler(data, p, round_fraction=0.5, batch_size=16,
                              seed=i) for i, p in enumerate(parts)]
    cfg = FLRunConfig(method="fedadp", rounds=1, local_epochs=1, lr=0.05,
                      engine="unified", attn_backend="flash")
    with pytest.raises(ValueError, match="attn_backend"):
        Simulator(VGGFamily(), cfgs, samplers, cfg, test).run()
