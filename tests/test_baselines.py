"""Baseline semantics: Clustered-FL clusters, FlexiFed common prefix."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg_family import scaled, vgg
from repro.core import ClusteredFL, FlexiFed, vgg_chain
from repro.models import vgg as V

KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite


def _params(archs):
    cfgs = [scaled(vgg(a), 0.125, 32) for a in archs]
    ps = [V.init_params(jax.random.fold_in(KEY, i), c)
          for i, c in enumerate(cfgs)]
    return cfgs, ps


def test_clustered_fl_averages_within_clusters_only():
    archs = ["vgg13", "vgg13", "vgg19"]
    cfgs, ps = _params(archs)
    algo = ClusteredFL(cfgs, [1, 1, 1])
    new = algo.round(list(ps), lambda k, p: p, 0)
    # the two vgg13 clients end identical; vgg19 untouched
    for a, b in zip(jax.tree.leaves(new[0]), jax.tree.leaves(new[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(new[2]), jax.tree.leaves(ps[2])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and vgg13 result is the average of the two inputs
    want = (np.asarray(ps[0]["out"]["w"]) + np.asarray(ps[1]["out"]["w"])) / 2
    np.testing.assert_allclose(np.asarray(new[0]["out"]["w"]), want,
                               rtol=1e-5)


def test_flexifed_common_prefix_extent():
    archs = ["vgg13", "vgg16-wider", "vgg19"]
    cfgs, ps = _params(archs)
    algo = FlexiFed(cfgs, [1, 1, 1], vgg_chain)
    common = algo._common_prefix(ps)
    # stages 1-2 have identical structure everywhere (2+2 convs); stage 3
    # diverges in depth (2 vs 3 vs 4 convs) at chain position 6... the
    # prefix must cover at least the first 4 convs and stop before any
    # width/depth mismatch.
    assert len(common) >= 4
    chain0 = vgg_chain(cfgs[0], ps[0])
    # verify every common position has identical layer-id across clients
    for pos in common:
        ids = {tuple(vgg_chain(c, p)[pos][0]) for c, p in zip(cfgs, ps)}
        assert len(ids) == 1


def test_flexifed_aggregates_prefix_across_all():
    archs = ["vgg13", "vgg19"]
    cfgs, ps = _params(archs)
    algo = FlexiFed(cfgs, [1, 1], vgg_chain)
    new = algo.round([jax.tree.map(jnp.array, p) for p in ps],
                     lambda k, p: p, 0)
    w0 = np.asarray(new[0]["stages"]["s0"]["c0"]["w"])
    w1 = np.asarray(new[1]["stages"]["s0"]["c0"]["w"])
    np.testing.assert_array_equal(w0, w1)
    want = (np.asarray(ps[0]["stages"]["s0"]["c0"]["w"])
            + np.asarray(ps[1]["stages"]["s0"]["c0"]["w"])) / 2
    np.testing.assert_allclose(w0, want, rtol=1e-5)
