"""HLO analyzer trip-count exactness + sharding-rule validity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.launch.hlo_analysis import analyze
from repro.sharding import rules


def test_analyzer_counts_scan_bodies_times_trip_count():
    N = 128

    def g(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, jnp.eye(N, dtype=jnp.float32), None,
                              length=7)
        return out

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((N, N), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert abs(r["dot_flops"] - 7 * 2 * N ** 3) / (7 * 2 * N ** 3) < 0.05
    # raw cost_analysis undercounts (counts the body once) — the reason
    # this analyzer exists:
    raw = c.cost_analysis()
    if isinstance(raw, (list, tuple)):        # older jax wraps per-device
        raw = raw[0]
    assert raw["flops"] < r["dot_flops"] / 2


def test_analyzer_nested_scans():
    N = 64

    def g(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, jnp.eye(N, dtype=jnp.float32), None,
                              length=5)
        return out

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((N, N), jnp.float32)).compile()
    r = analyze(c.as_text())
    want = 15 * 2 * N ** 3
    assert abs(r["dot_flops"] - want) / want < 0.05


def _abstract_mesh():
    # jax>=0.4.36 takes ((name, size), ...); older takes (sizes, names)
    try:
        return AbstractMesh((("data", 16), ("model", 16)))
    except TypeError:
        return AbstractMesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", ["glm4-9b", "mixtral-8x7b",
                                  "recurrentgemma-9b", "deepseek-v2-236b"])
def test_param_specs_are_valid_and_divisible(arch):
    from repro.configs import get_config
    from repro.launch.specs import param_sds
    cfg = get_config(arch).with_dtype("bfloat16")
    sds = param_sds(cfg)
    mesh = _abstract_mesh()
    specs = rules.param_specs(sds, mesh, ("data",))

    def check(path, leaf, spec):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            extent = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % extent == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        check, sds, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # large matrices must actually be sharded (FSDP feasibility) — except
    # the MoE router, which stays replicated by design (shard_map reads it
    # whole on every shard; ~100MB worst case, documented in rules.py).
    big = [(p, s) for (p, l), s in zip(
        jax.tree_util.tree_leaves_with_path(sds),
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        if np.prod(l.shape) > 4e6 and "router" not in str(p)]
    assert all(any(e is not None for e in s) for _, s in big), \
        [p for p, s in big if all(e is None for e in s)]


def test_expert_parallel_vs_tensor_parallel_choice():
    from repro.configs import get_config
    from repro.launch.specs import param_sds
    mesh = _abstract_mesh()
    # deepseek: 160 experts % 16 == 0 -> expert parallel (E axis sharded)
    ds = param_sds(get_config("deepseek-v2-236b").with_dtype("bfloat16"))
    specs = rules.param_specs(ds, mesh, ("data",))
    wg_spec = specs["units"]["b0"]["moe"]["wg"]
    assert wg_spec[1] == "model"
    # mixtral: 8 % 16 != 0 -> tensor parallel on F
    mx = param_sds(get_config("mixtral-8x7b").with_dtype("bfloat16"))
    specs = rules.param_specs(mx, mesh, ("data",))
    wg_spec = specs["units"]["b0"]["moe"]["wg"]
    assert wg_spec[1] is None and wg_spec[-1] == "model"
