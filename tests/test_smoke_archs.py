"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward and one train step on CPU with
correct output shapes and no NaNs; decode shapes run one cached step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.launch.steps import lm_loss, make_train_step
from repro.models import get_model
from repro.optim import sgd

KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite
B, S = 2, 16


def _setup(arch):
    cfg = reduced(get_config(arch), d_model=128)
    if cfg.moe is not None:  # deterministic decode tests need headroom
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = get_model(cfg)
    p = m.init(KEY)
    aux = None
    if cfg.encoder is not None:
        aux = jax.random.normal(KEY, (B, cfg.encoder.n_ctx, cfg.d_model))
    elif cfg.frontend is not None and cfg.frontend.kind == "vision":
        aux = jax.random.normal(KEY, (B, cfg.frontend.n_prefix, cfg.d_model))
    return cfg, m, p, aux


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg, m, p, aux = _setup(arch)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits = m.forward(p, toks, aux=aux)
    n_prefix = (cfg.frontend.n_prefix
                if cfg.frontend and cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (B, S + n_prefix, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nan(arch):
    cfg, m, p, aux = _setup(arch)
    opt = sgd(1e-2)
    step = make_train_step(cfg, opt)
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if aux is not None:
        batch["aux"] = aux
    new_p, _, metrics = step(p, opt.init(p), 0, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p, new_p)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    cfg, m, p, aux = _setup(arch)
    n_prefix = (cfg.frontend.n_prefix
                if cfg.frontend and cfg.frontend.kind == "vision" else 0)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    full = m.forward(p, toks, aux=aux)
    last, cache = m.prefill(p, toks[:, :S], aux=aux,
                            cache_len=n_prefix + S + 4)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, n_prefix + S - 1]),
                               rtol=2e-4, atol=2e-4)
    dec, _ = m.decode_step(p, toks[:, S:S + 1], cache,
                           jnp.int32(n_prefix + S))
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full[:, n_prefix + S]),
                               rtol=2e-3, atol=2e-3)
