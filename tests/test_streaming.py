"""Streaming O(P·k_chunk) + two-level hierarchical aggregation (ISSUE 8).

Four layers of evidence:
  * layout equivalence — ``fedavg_stacked`` under "stream" equals the
    "plane" and "leaf" layouts on REAL coverage cohorts (width+depth
    heterogeneous VGG and Transformer-FFN: family-built masks and
    multiplicities, renorm + fallback),
  * hierarchy exactness — ``fedavg_hierarchical`` equals the flat
    aggregation for every edge-group split of the cohort (the masked
    weighted sum is associative; groups may be uneven, reordered,
    singleton or the whole cohort),
  * the memory envelope — ``PlaneAccumulator``'s accounted peak is
    O(P·k_chunk): INDEPENDENT of how many total rows stream through,
    and far below the O(P·K) resident plane it replaces,
  * the engine — a chunked streaming round (``agg_layout="stream"``,
    pinned ``k_chunk``) reproduces the plane-layout round bit-for-bit
    modulo float reassociation, and the shard_mapped edge reduce over a
    real 4-device mesh (subprocess — the suite's own jax is pinned to
    one device) matches the single-device round.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.vgg_family import VGGConfig
from repro.core import (TransformerFamily, VGGFamily, coverage_mask,
                        fedavg_stacked, multiplicity, stack_trees, tfamily)
from repro.core.aggregation import (fedavg_hierarchical, last_agg_stats,
                                    subset_weights)
from repro.core.netchange import round_embed_seed
from repro.data import EASY, ClientSampler, image_classification, iid_partition
from repro.fl import FLRunConfig, Simulator
from repro.kernels.fedavg import ops as kops

ATOL = 5e-6          # reassociation headroom on ~1e-7 kernels


def _tiny_vgg(name, stages):
    return VGGConfig(name=name, stages=stages, classifier=(16,),
                     n_classes=4, image_size=8)


def _vgg_width_cohort(K=6):
    family = VGGFamily()
    base = [_tiny_vgg("w1", ((8,), (8,))),
            _tiny_vgg("w2", ((8,), (12, 8))),
            _tiny_vgg("w3", ((12, 8), (12, 8)))]
    return family, [base[k % len(base)] for k in range(K)]


def _tffn_width_cohort(K=4):
    family = TransformerFamily()
    base = reduced(get_config("glm4-9b"), n_units=2, d_model=32)
    vs = [tfamily.make_variant(base, n_units=2, ffn_scale=0.5),
          tfamily.make_variant(base, n_units=1, ffn_scale=1.0)]
    return family, [vs[k % len(vs)] for k in range(K)]


def _coverage_fixture(family, cfgs, *, seed=0):
    """Stacked global-shaped trees + family-built masks/mult + fallback
    — the heaviest aggregation variant, on a real union architecture."""
    gcfg = family.union(list(cfgs))
    key = jax.random.PRNGKey(11)
    trees = [family.init(jax.random.fold_in(key, k), gcfg)
             for k in range(len(cfgs))]
    masks, mults = [], []
    for k, c in enumerate(cfgs):
        s = round_embed_seed(seed, 0, k)
        masks.append(coverage_mask(family, c, gcfg, policy="loose", seed=s))
        mults.append(multiplicity(family, c, gcfg, seed=s))
    fallback = family.init(jax.random.fold_in(key, 999), gcfg)
    w = subset_weights([k + 1 for k in range(len(cfgs))])
    return (stack_trees(trees), w, stack_trees(masks), stack_trees(mults),
            fallback)


def _assert_trees_close(a, b, *, atol, msg):
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   atol=atol, err_msg=msg)


@pytest.mark.parametrize("cohort", ["vgg", "tffn"])
def test_stream_equals_plane_equals_leaf_width_cohorts(cohort):
    """The three layouts are the same math on family-real coverage
    inputs (masks + mult + renorm + fallback), for every chunk size —
    including one that does NOT divide K (ragged tail chunk)."""
    family, cfgs = (_vgg_width_cohort() if cohort == "vgg"
                    else _tffn_width_cohort())
    stacked, w, masks, mult, fb = _coverage_fixture(family, cfgs)
    kw = dict(masks=masks, mult=mult, renorm=True, fallback=fb)
    leaf = fedavg_stacked(stacked, w, layout="leaf", **kw)
    plane = fedavg_stacked(stacked, w, layout="plane", **kw)
    _assert_trees_close(leaf, plane, atol=ATOL, msg=f"{cohort}: plane")
    for kc in (1, 2, len(cfgs) - 1, len(cfgs)):
        stream = fedavg_stacked(stacked, w, layout="stream", k_chunk=kc,
                                **kw)
        _assert_trees_close(plane, stream, atol=ATOL,
                            msg=f"{cohort}: stream kc={kc}")
        stats = last_agg_stats()
        assert stats["layout"] == "stream" and stats["k_chunk"] == kc


def test_stream_layout_plain_eq1():
    """Unmasked Eq. 1 (no coverage): stream == plane == leaf too — the
    dot-product fast path of the streaming oracle is the same sum."""
    family, cfgs = _vgg_width_cohort(K=5)
    stacked, w, _, _, _ = _coverage_fixture(family, cfgs)
    leaf = fedavg_stacked(stacked, w, layout="leaf")
    for layout, kw in (("plane", {}), ("stream", dict(k_chunk=2))):
        got = fedavg_stacked(stacked, w, layout=layout, **kw)
        _assert_trees_close(leaf, got, atol=ATOL, msg=layout)


def test_hierarchical_equals_flat_for_every_split():
    """Two-level edge reduce == flat aggregation for every partition of
    the cohort into edge groups: even, uneven, reordered, singleton,
    whole-cohort. Exact up to reassociation — no renormalization happens
    per group (weights stay GLOBAL subset weights)."""
    family, cfgs = _vgg_width_cohort(K=6)
    stacked, w, masks, mult, fb = _coverage_fixture(family, cfgs)
    kw = dict(masks=masks, mult=mult, renorm=True, fallback=fb)
    flat = fedavg_stacked(stacked, w, layout="plane", **kw)
    splits = [
        [[0, 1, 2, 3, 4, 5]],                       # whole cohort
        [[0, 1], [2, 3], [4, 5]],                   # even edges
        [[0], [1, 2, 3, 4, 5]],                     # uneven
        [[5, 3, 1], [0, 2, 4]],                     # reordered rows
        [[0], [1], [2], [3], [4], [5]],             # one client per edge
    ]
    for groups in splits:
        got = fedavg_hierarchical(stacked, w, groups=groups, k_chunk=2,
                                  **kw)
        _assert_trees_close(flat, got, atol=ATOL, msg=f"groups={groups}")


def test_hierarchical_rejects_bad_groups():
    family, cfgs = _vgg_width_cohort(K=4)
    stacked, w, *_ = _coverage_fixture(family, cfgs)
    for bad in ([[0, 1], [2]],          # missing a client
                [[0, 1], [1, 2, 3]],    # duplicated client
                [[0, 1, 2, 3, 4]]):     # out-of-range client
        with pytest.raises(ValueError):
            fedavg_hierarchical(stacked, w, groups=bad)


def test_accumulator_peak_memory_is_o_p_kchunk():
    """The accounted aggregation footprint is O(P·k_chunk): streaming
    8 rows and 64 rows through the same accumulator shape reports the
    SAME peak, and that peak stays far below the O(P·K) resident plane
    the whole-plane layout would allocate at K=64."""
    n, kc = 50_000, 4
    rng = np.random.default_rng(0)

    def stream(total_rows):
        acc = kops.PlaneAccumulator(n, use_kernel=False, k_hint=kc)
        for _ in range(total_rows // kc):
            chunk = jnp.asarray(rng.normal(size=(kc, n)), jnp.float32)
            wk = jnp.full((kc,), 1.0 / total_rows, jnp.float32)
            acc.update(chunk, wk)
        return acc.stats()

    s8, s64 = stream(8), stream(64)
    assert s8["peak_bytes"] == s64["peak_bytes"], (s8, s64)
    assert s64["rows"] == 64 and s64["peak_chunk_rows"] == kc
    whole_plane_bytes = 4 * 64 * n
    assert s64["peak_bytes"] < whole_plane_bytes / 4, (
        s64["peak_bytes"], whole_plane_bytes)
    # the envelope is exactly buffers + one chunk's streamed operands
    assert s64["peak_bytes"] == s64["buffer_bytes"] + s64["chunk_bytes"]


def _sim_cohort():
    import dataclasses
    cfgs = [_tiny_vgg("t2", ((8,), (8,))), _tiny_vgg("t3", ((8,), (8, 8))),
            _tiny_vgg("t4", ((8, 8), (8, 8))), _tiny_vgg("t2b", ((8,), (8,)))]
    spec = dataclasses.replace(EASY, image_size=8, n_classes=4)
    data = image_classification(spec, 64, seed=0)
    test = image_classification(spec, 32, seed=9)
    parts = iid_partition(64, len(cfgs), seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i) for i, p in enumerate(parts)]

    return cfgs, samplers, test


def test_engine_streaming_round_matches_plane_round():
    """A full Simulator run with agg_layout="stream" (chunked training
    + PlaneAccumulator aggregation, k_chunk=2 over K=4) reproduces the
    plane-layout run — history and global params."""
    cfgs, samplers, test = _sim_cohort()
    out = {}
    for layout, kc in (("plane", None), ("stream", 2)):
        cfg = FLRunConfig(method="fedadp", rounds=2, local_epochs=1,
                          lr=0.05, momentum=0.9, engine="unified",
                          agg_layout=layout, k_chunk=kc)
        sim = Simulator(VGGFamily(), cfgs, samplers(), cfg, test)
        out[layout] = sim.run()
    np.testing.assert_allclose(out["plane"]["history"],
                               out["stream"]["history"], atol=1e-5)
    _assert_trees_close(out["plane"]["global_params"],
                        out["stream"]["global_params"], atol=1e-5,
                        msg="global params")


def test_engine_stream_agg_stats_report_chunked_peak():
    """The engine's ``agg_stats()`` surface: a streaming round reports
    layout/k_chunk and a peak below the whole-plane footprint."""
    cfgs, samplers, test = _sim_cohort()
    cfg = FLRunConfig(method="fedadp", rounds=1, local_epochs=1, lr=0.05,
                      engine="unified", agg_layout="stream", k_chunk=1)
    sim = Simulator(VGGFamily(), cfgs, samplers(), cfg, test)
    sim.run()
    be = next(b for k, b in sim._backends.items() if k[0] == "unified")
    stats = be.engine.agg_stats()
    assert stats["layout"] == "stream" and stats["k_chunk"] == 1
    assert stats["peak_chunk_rows"] == 1 and stats["rows"] == len(cfgs)
    # the envelope carries NO K term: three (padded) buffers plus one
    # k_chunk-row chunk's streamed operands (≤ 3 streams), whatever the
    # cohort size
    assert stats["buffer_bytes"] == 3 * 4 * stats["padded"]
    assert stats["chunk_bytes"] <= 3 * 4 * stats["padded"] * stats["k_chunk"]
    assert stats["peak_bytes"] == stats["buffer_bytes"] + stats["chunk_bytes"]


_EDGE_SCRIPT = textwrap.dedent("""
    import os
    import jax
    assert jax.device_count() == 4, jax.device_count()
    import numpy as np
    from repro.core import VGGFamily
    from repro.configs.vgg_family import VGGConfig
    from repro.data import (EASY, ClientSampler, image_classification,
                            iid_partition)
    from repro.fl import FLRunConfig, Simulator
    from repro.sharding import cohort_mesh
    import dataclasses

    def tiny(name, stages):
        return VGGConfig(name=name, stages=stages, classifier=(16,),
                         n_classes=4, image_size=8)

    cfgs = [tiny("t2", ((8,), (8,))), tiny("t3", ((8,), (8, 8))),
            tiny("t4", ((8, 8), (8, 8))), tiny("t2b", ((8,), (8,)))]
    spec = dataclasses.replace(EASY, image_size=8, n_classes=4)
    data = image_classification(spec, 64, seed=0)
    test = image_classification(spec, 32, seed=9)
    parts = iid_partition(64, len(cfgs), seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i) for i, p in enumerate(parts)]

    cfg = FLRunConfig(method="fedadp", rounds=2, local_epochs=1, lr=0.05,
                      momentum=0.9, engine="unified", agg_mode="coverage")
    outs = {}
    for tag, mesh in (("flat", None), ("mesh", cohort_mesh(len(cfgs)))):
        sim = Simulator(VGGFamily(), cfgs, samplers(), cfg, test, mesh=mesh)
        outs[tag] = sim.run()
        if tag == "mesh":
            assert mesh is not None, "cohort_mesh gave no mesh on 4 devices"
            be = next(b for k, b in sim._backends.items()
                      if k[0] == "unified")
            stats = be.engine.agg_stats()
            assert stats["layout"] == "edge", stats
            assert stats["edges"] == 4, stats
    np.testing.assert_allclose(outs["flat"]["history"],
                               outs["mesh"]["history"], atol=1e-4)
    import jax.tree_util as jtu
    for a, b in zip(jax.tree.leaves(outs["flat"]["global_params"]),
                    jax.tree.leaves(outs["mesh"]["global_params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
    print("EDGE-REDUCE-OK")
""")


def test_edge_reduce_on_four_device_mesh_subprocess():
    """The two-level hierarchical reduce under a REAL 4-device client
    mesh: the shard_mapped edge pre-reduce (one partial triple per mesh
    slot, psum to the global reduce) matches the flat single-device
    round to 1e-4. Runs in a subprocess because this suite's jax is
    pinned to the real single-device topology (tests/conftest.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _EDGE_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-4000:])
    assert "EDGE-REDUCE-OK" in proc.stdout, proc.stdout[-2000:]
