"""Quantized wire format (ISSUE 9, DESIGN.md §10): core.quant algebra,
the fused dequantize-accumulate kernel, the PlaneAccumulator's compressed
update, config validation, and end-to-end accuracy parity of compressed
federated runs (bf16 / int8 + error feedback) against the f32 wire."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.vgg_family import scaled, vgg
from repro.core import TransformerFamily, VGGFamily, quant, tfamily
from repro.data import (EASY, ClientSampler, image_classification,
                        iid_partition)
from repro.data.synthetic import lm_sequences
from repro.fl import FLRunConfig, Simulator
from repro.kernels.fedavg import ops
from repro.kernels.fedavg.ref import plane_accum_ref, plane_accum_q_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- quant core
def test_int8_roundtrip_error_bounded():
    """Symmetric per-tile int8: |x - deq(q(x))| <= scale/2 everywhere,
    and all-zero tiles round-trip exactly (safe scale)."""
    x = jnp.asarray(RNG.standard_normal((3, 1000)) * 5.0, jnp.float32)
    x = x.at[1].set(0.0)                       # an all-zero row
    vals, scales = quant.quantize(x, "int8", tile=128)
    assert vals.dtype == jnp.int8
    assert scales.shape == (3, quant.n_tiles(1000, 128))
    deq = np.asarray(quant.dequantize(vals, scales, tile=128))
    step = np.repeat(np.asarray(scales), 128, axis=1)[:, :1000]
    assert (np.abs(deq - np.asarray(x)) <= step / 2 + 1e-7).all()
    np.testing.assert_array_equal(deq[1], 0.0)


def test_bf16_wire_is_the_cast():
    x = jnp.asarray(RNG.standard_normal((2, 300)), jnp.float32)
    vals, scales = quant.quantize(x, "bf16")
    assert scales is None and vals.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(quant.dequantize(vals, scales)),
        np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32)))


def test_error_feedback_identity_exact():
    """deq(q) + e' == x + e bit-for-bit: the quantization error is fully
    captured by the residual, nothing is ever silently dropped."""
    x = jnp.asarray(RNG.standard_normal((2, 700)), jnp.float32)
    e = jnp.asarray(RNG.standard_normal((2, 700)) * 0.05, jnp.float32)
    for fmt in ("bf16", "int8"):
        vals, scales, e2 = quant.encode(x, e, fmt, tile=256)
        lhs = np.asarray(quant.dequantize(vals, scales, tile=256)) \
            + np.asarray(e2)
        np.testing.assert_array_equal(lhs, np.asarray(x + e))
    # f32 wire: identity quantizer — x + e ships exactly, residual drains
    vals, scales, e2 = quant.encode(x, e, "f32")
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(x + e))
    np.testing.assert_array_equal(np.asarray(e2), 0.0)


def test_masked_encode_zeroes_off_mask():
    """Sparse wire: off-mask coordinates carry no payload information —
    values, scales' support, and the residual are all zero there."""
    x = jnp.asarray(RNG.standard_normal((2, 512)), jnp.float32)
    e = jnp.asarray(RNG.standard_normal((2, 512)), jnp.float32)
    mask = jnp.asarray(RNG.integers(0, 2, (2, 512)), jnp.float32)
    vals, scales, e2 = quant.encode(x, e, "int8", tile=128, mask=mask)
    off = np.asarray(mask) == 0.0
    np.testing.assert_array_equal(np.asarray(vals)[off], 0)
    np.testing.assert_array_equal(np.asarray(e2)[off], 0.0)
    # on-mask the EF identity still holds exactly
    on = ~off
    lhs = np.asarray(quant.dequantize(vals, scales, tile=128)) \
        + np.asarray(e2)
    np.testing.assert_array_equal(lhs[on], np.asarray(x + e)[on])


def test_payload_bytes():
    """Dense payload = n·itemsize + scale grid; sparse payload counts
    exactly the covered coordinates."""
    n, tile = 1000, 256
    nt = quant.n_tiles(n, tile)
    assert quant.payload_nbytes("f32", n) == 4 * n
    assert quant.payload_nbytes("bf16", n) == 2 * n
    assert quant.payload_nbytes("int8", n, tile=tile) == n + 4 * nt
    for covered in (0, 1, 137, n):
        assert quant.payload_nbytes("int8", n, tile=tile, covered=covered) \
            == covered * quant.wire_itemsize("int8") + 4 * nt
        assert quant.payload_nbytes("bf16", n, covered=covered) \
            == covered * 2


def test_validate_tile_rejects_bad_tiles():
    for bad in (0, -128, 100, 130, 64, True, None, 128.0):
        with pytest.raises((ValueError, TypeError)):
            quant.validate_tile(bad)
    assert quant.validate_tile(128) == 128
    assert quant.validate_tile(512) == 512


# ------------------------------------------------- fused kernel vs ref
def _bufs(n):
    z = lambda: jnp.zeros((n,), jnp.float32)  # noqa: E731
    return z(), z(), z()


@pytest.mark.parametrize("variant", ["plain", "masked_mult", "fold"])
def test_accum_q_kernel_matches_ref_and_dequant(variant):
    """The fused dequantize-accumulate kernel == the jnp reference ==
    dequantize-then-f32-accumulate, to 1e-6."""
    K, n, tile = 3, 4096 * 2 + 517, 256
    x = jnp.asarray(RNG.standard_normal((K, n)), jnp.float32)
    w = jnp.asarray([0.5, 0.3, 0.2], jnp.float32)
    m = jnp.asarray(RNG.integers(0, 2, (K, n)), jnp.float32)
    xq, s = quant.quantize(x, "int8", tile=tile, mask=m)
    deq = quant.dequantize(xq, s, tile=tile)
    kw = dict(tile=tile, interpret=True)
    if variant == "plain":
        args, ref_kw, f32_chunk, f32_kw = (xq, s, w), {}, deq, {}
    elif variant == "masked_mult":
        mu = jnp.asarray(RNG.integers(1, 3, (K, n)), jnp.float32)
        args = (xq, s, w)
        ref_kw = dict(masks=m, mult=mu)
        f32_chunk, f32_kw = deq, dict(masks=m, mult=mu)
    else:  # fold: uncovered coordinates carry the global row
        base = jnp.asarray(RNG.standard_normal((n,)), jnp.float32)
        args = (xq, s, w)
        ref_kw = dict(masks=m, base=base)
        f32_chunk = deq * m + base[None, :] * (1 - m)   # then UNMASKED
        f32_kw = {}
    num_k, den_k, cov_k = ops.plane_accum_q(
        *_bufs(n), *args, use_kernel=True, **ref_kw, **kw)
    num_r, den_r, cov_r = ops.plane_accum_q(
        *_bufs(n), *args, use_kernel=False, **ref_kw, **kw)
    num_f, den_f, cov_f = ops.plane_accum(
        *_bufs(n), f32_chunk, w, use_kernel=False, **f32_kw)
    for a, b, c in ((num_k, num_r, num_f), (den_k, den_r, den_f),
                    (cov_k, cov_r, cov_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)


def test_accum_q_ref_matches_plane_accum_ref_on_2d_buffers():
    """The (1, N) reference surface: plane_accum_q_ref is exactly
    dequantize + plane_accum_ref."""
    K, n, tile = 2, 512, 128
    x = jnp.asarray(RNG.standard_normal((K, n)), jnp.float32)
    w = jnp.asarray([0.6, 0.4], jnp.float32)
    xq, s = quant.quantize(x, "int8", tile=tile)
    z = lambda: jnp.zeros((1, n), jnp.float32)  # noqa: E731
    got = plane_accum_q_ref(z(), z(), z(), xq, s, w, tile=tile)
    want = plane_accum_ref(z(), z(), z(),
                           quant.dequantize(xq, s, tile=tile), w)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_update_q_matches_update_on_dequantized_chunks():
    """PlaneAccumulator.update_q (int8 chunks + scales) folds the same
    numbers as .update on the dequantized f32 chunks, and its peak
    memory is K-independent (the streaming contract survives
    compression)."""
    n, tile, kc = 4096 * 3 + 101, 256, 2
    w_all = jnp.asarray(RNG.random((8,)) + 0.1, jnp.float32)
    x_all = jnp.asarray(RNG.standard_normal((8, n)), jnp.float32)
    peaks = {}
    for K in (4, 8):
        acc_q = ops.PlaneAccumulator(n, use_kernel=False, k_hint=kc,
                                     q_tile=tile)
        acc_f = ops.PlaneAccumulator(n, use_kernel=False, k_hint=kc)
        for lo in range(0, K, kc):
            x = x_all[lo:lo + kc]
            xq, s = quant.quantize(x, "int8", tile=tile)
            acc_q.update_q(xq, s, w_all[lo:lo + kc])
            acc_f.update(quant.dequantize(xq, s, tile=tile),
                         w_all[lo:lo + kc])
        gq = acc_q.finish()
        gf = acc_f.finish()
        np.testing.assert_allclose(np.asarray(gq), np.asarray(gf),
                                   atol=1e-6)
        peaks[K] = acc_q.stats()["peak_bytes"]
    assert peaks[4] == peaks[8], "compressed peak memory must not scale with K"
    # int8 chunks are 4x narrower than f32 ones (modulo the scale grid)
    f32_chunk = ops.PlaneAccumulator(n, use_kernel=False, k_hint=kc)
    f32_chunk.update(x_all[:kc], w_all[:kc])
    assert peaks[4] < f32_chunk.stats()["peak_bytes"]


# ------------------------------------------------------------ validation
def test_run_config_validates_wire_combinations():
    with pytest.raises(ValueError, match="wire="):
        FLRunConfig(wire="fp4")
    with pytest.raises(ValueError, match="tile"):
        FLRunConfig(wire="int8", wire_tile=100)
    with pytest.raises(ValueError, match="loop"):
        FLRunConfig(wire="int8", engine="loop")
    with pytest.raises(ValueError, match="plane"):
        FLRunConfig(wire="int8", agg_layout="plane")
    with pytest.raises(ValueError, match="wire layer"):
        FLRunConfig(wire="int8", method="clustered")
    with pytest.raises(ValueError, match="wire_sparse"):
        FLRunConfig(wire_sparse=True)                   # needs a wire
    with pytest.raises(ValueError, match="coverage"):
        FLRunConfig(wire="int8", wire_sparse=True)      # needs agg_mode
    # the valid combinations construct
    FLRunConfig(wire="bf16")
    FLRunConfig(wire="int8", wire_tile=512, agg_layout="stream")
    FLRunConfig(wire="int8", wire_sparse=True, agg_mode="coverage")


# ------------------------------------------------------------ end-to-end
def _vgg_width_setup(n=240, n_eval=360):
    """A width-heterogeneous tier-1 VGG cohort (vgg16-wider widens a
    stage-4 conv) with a generous eval set: one flipped prediction moves
    accuracy by 1/360, well under the 1e-2 parity budget."""
    family = VGGFamily()
    cfgs = [scaled(vgg(a), 0.125, 64)
            for a in ("vgg13", "vgg16", "vgg16-wider")]
    data = image_classification(EASY, n, seed=0)
    test = image_classification(EASY, n_eval, seed=99)
    parts = iid_partition(n, len(cfgs), seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=32,
                              seed=i) for i, p in enumerate(parts)]

    return family, cfgs, samplers, test


def _run_wire(family, cfgs, samplers, test, *, wire, rounds=3, **cfg_kw):
    rc = FLRunConfig(method="fedadp", rounds=rounds, local_epochs=1,
                     lr=0.05, momentum=0.9, eval_every=rounds,
                     engine="unified", wire=wire, **cfg_kw)
    sim = Simulator(family, cfgs, samplers(), rc, test)
    out = sim.run()
    backend = next(iter(sim._backends.values()))
    return out, backend


def test_bf16_wire_matches_f32_aggregation():
    """bf16 wire vs f32 wire on the width VGG cohort: final accuracy
    agrees to 1e-2 and the wire stats report the exact 2x payload."""
    family, cfgs, samplers, test = _vgg_width_setup()
    f32, _ = _run_wire(family, cfgs, samplers, test, wire="f32")
    bf16, backend = _run_wire(family, cfgs, samplers, test, wire="bf16")
    assert abs(f32["final_acc"] - bf16["final_acc"]) <= 1e-2
    ws = backend.wire_stats()
    assert ws["wire"] == "bf16" and ws["reduction"] == 2.0


def test_int8_wire_with_ef_converges_vgg_width():
    """int8 + error feedback on the width VGG cohort: <= 1e-2 final
    accuracy delta vs the f32 wire, >= 3.9x byte reduction dense."""
    family, cfgs, samplers, test = _vgg_width_setup()
    f32, _ = _run_wire(family, cfgs, samplers, test, wire="f32")
    q, backend = _run_wire(family, cfgs, samplers, test, wire="int8")
    assert abs(f32["final_acc"] - q["final_acc"]) <= 1e-2
    ws = backend.wire_stats()
    assert ws["wire"] == "int8" and ws["reduction"] > 3.9
    # the sparse coverage wire beats 4x (only covered coordinates ship).
    # One round: the quantization error alone separates the runs — the
    # global params agree to quantization precision (longer coverage-mode
    # runs at this toy scale are chaotic under ANY tiny perturbation, so
    # multi-round accuracy parity would test noise, not the wire)
    f32c, _ = _run_wire(family, cfgs, samplers, test, wire="f32",
                        agg_mode="coverage", rounds=1)
    qs, bs = _run_wire(family, cfgs, samplers, test, wire="int8",
                       wire_sparse=True, agg_mode="coverage", rounds=1)
    assert abs(f32c["final_acc"] - qs["final_acc"]) <= 1e-2
    for a, b in zip(jax.tree.leaves(f32c["global_params"]),
                    jax.tree.leaves(qs["global_params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)
    assert bs.wire_stats()["reduction"] >= 4.0


def test_int8_wire_with_ef_converges_tffn_width():
    """int8 + error feedback on the width transformer-FFN cohort
    (d_ff + depth heterogeneous): <= 1e-2 final accuracy delta."""
    family = TransformerFamily()
    base = reduced(get_config("glm4-9b"), n_units=2, d_model=32)
    cfgs = [tfamily.make_variant(base, n_units=2, ffn_scale=0.5),
            tfamily.make_variant(base, n_units=1, ffn_scale=1.0)]
    assert family.segment_representable(cfgs)
    seqs = np.asarray(lm_sequences(base.vocab_size, 72, 16, seed=0))
    data = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
    test = {"tokens": seqs[:48, :-1], "labels": seqs[:48, 1:]}
    parts = iid_partition(72, len(cfgs), seed=0)

    def samplers():
        return [ClientSampler(data, p, round_fraction=0.5, batch_size=8,
                              seed=i) for i, p in enumerate(parts)]

    f32, _ = _run_wire(family, cfgs, samplers, test, wire="f32")
    q, backend = _run_wire(family, cfgs, samplers, test, wire="int8")
    assert abs(f32["final_acc"] - q["final_acc"]) <= 1e-2
    assert backend.wire_stats()["wire"] == "int8"
