"""Transformer-family NetChange (beyond-paper): function preservation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import tfamily
from repro.models import get_model

KEY = jax.random.PRNGKey(0)  # fedlint: ignore[FDL003] shared fixture; CPU-only test suite


def _variant_pair(arch, **kw):
    cfg = reduced(get_config(arch), n_units=2, d_model=128)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    var = tfamily.make_variant(cfg, **kw)
    if var.moe is not None:
        var = dataclasses.replace(var, moe=dataclasses.replace(
            var.moe, capacity_factor=8.0))
    return var, tfamily.union([var, cfg])


@pytest.mark.parametrize("arch,kw", [
    ("glm4-9b", dict(n_units=1, ffn_scale=0.5)),
    ("gemma-7b", dict(n_units=1, ffn_scale=0.5)),
    ("recurrentgemma-9b", dict(n_units=1, ffn_scale=0.5)),
    ("xlstm-125m", dict(n_units=1)),
    ("internvl2-1b", dict(n_units=1, ffn_scale=0.5)),
])
def test_up_preserves_function(arch, kw):
    var, uni = _variant_pair(arch, **kw)
    m_v = get_model(var)
    p = m_v.init(KEY)
    toks = jax.random.randint(KEY, (2, 12), 0, var.vocab_size)
    aux = None
    if var.frontend is not None and var.frontend.kind == "vision":
        aux = jax.random.normal(KEY, (2, var.frontend.n_prefix, var.d_model))
    y0 = m_v.forward(p, toks, aux=aux)
    pg = tfamily.up(p, var, uni, seed=3)
    y1 = get_model(uni).forward(pg, toks, aux=aux)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("arch,kw", [
    ("glm4-9b", dict(n_units=1, ffn_scale=0.5)),
    ("recurrentgemma-9b", dict(n_units=1, ffn_scale=0.5)),
])
def test_fold_roundtrip(arch, kw):
    var, uni = _variant_pair(arch, **kw)
    m_v = get_model(var)
    p = m_v.init(KEY)
    toks = jax.random.randint(KEY, (2, 10), 0, var.vocab_size)
    y0 = m_v.forward(p, toks)
    pg = tfamily.up(p, var, uni, seed=3)
    pb = tfamily.down(pg, uni, var, seed=3, mode="fold")
    y2 = m_v.forward(pb, toks)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y2),
                               rtol=5e-4, atol=5e-4)


def test_moe_expert_widening_exact_under_soft_routing():
    cfg = reduced(get_config("mixtral-8x7b"), n_units=2, d_model=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, n_experts=4, top_k=4, capacity_factor=8.0))
    var = tfamily.make_variant(cfg, n_units=1, n_experts=2)
    var = dataclasses.replace(var, moe=dataclasses.replace(
        var.moe, top_k=2, capacity_factor=8.0))
    uni = tfamily.union([var, cfg])
    m_v = get_model(var)
    p = m_v.init(KEY)
    toks = jax.random.randint(KEY, (2, 10), 0, var.vocab_size)
    y0 = m_v.forward(p, toks)
    y1 = get_model(uni).forward(tfamily.up(p, var, uni, seed=1), toks)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-3, atol=1e-3)


def test_down_paper_produces_variant_shapes():
    var, uni = _variant_pair("glm4-9b", n_units=1, ffn_scale=0.5)
    gp = get_model(uni).init(KEY)
    cp = tfamily.down(gp, uni, var, mode="paper")
    want = jax.tree.map(lambda l: l.shape, get_model(var).init(KEY))
    got = jax.tree.map(lambda l: l.shape, cp)
    assert want == got


def test_union_takes_elementwise_max():
    cfg = reduced(get_config("glm4-9b"), n_units=2)   # 4 layers total
    a = tfamily.make_variant(cfg, n_units=1, ffn_scale=0.5)   # shallow, wide
    b = tfamily.make_variant(cfg, n_units=2, ffn_scale=0.25)  # deeper, narrow
    u = tfamily.union([a, b])
    assert u.n_layers == b.n_layers  # deepest cohort member
    assert u.d_ff == a.d_ff          # widest cohort member
