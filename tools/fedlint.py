#!/usr/bin/env python
"""fedlint — standalone entry point for ``repro.analysis``.

Same flags as ``python -m repro.analysis``; exists so the checker runs
from a clean checkout without exporting PYTHONPATH:

    ./tools/fedlint.py                      # all static passes
    ./tools/fedlint.py --pass lint          # AST rules only (no jax work)
    ./tools/fedlint.py --pass contracts --quick
"""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
