"""Segment operators — the width embedding as an explicit linear map.

NetChange's To-Wider is deterministic in ``(tag, old, new, seed)``
(``netchange.dup_mapping``), so a client's place in the union
architecture is a *linear operator*: ``up(p) = E p + filler`` where E
duplicates client coordinates into union *segments* (the union channels
that copy one client channel) and scales outgoing duplicates by the
inverse group size (Net2Net split). This module makes E's structure
first-class:

  * a family's ``segment_spec(client_cfg, global_cfg, seed)`` names, per
    union-tree leaf, the widened axes and the segment id of every union
    index along them (``AxisSeg``);
  * ``grad_matrix`` builds the axis factor of ``E Eᵀ`` — the operator
    that makes union-space SGD *equal* client-space SGD: the loop
    reference trains ``p ← p − lr ∇L(p)`` and ``∇_p L(E p) = Eᵀ g``, so
    the stacked engine must step ``u ← u − lr (E Eᵀ) g`` to keep
    ``u = E p`` exactly. Per axis that is segment-sum (duplicated axes)
    with a ``1/c²`` scale on split (outgoing) axes;
  * ``mean_matrix`` builds the axis factor of the *idempotent* projector
    ``E (EᵀE)⁻¹ Eᵀ`` onto image(E) — the segment mean, which for both
    axis roles is also exactly ``up(down(·))`` under
    ``narrow_mode="fold"``;
  * ``multiplicity_tree`` gives per-coordinate duplication counts
    ``m_kj`` for the multiplicity-aware coverage average (a client
    channel duplicated m times contributes weight ``W_k/m`` per copy, so
    its total stays ``W_k`` — ``core.aggregation``).

Everything here is plain data (numpy matrices keyed by tree paths); the
engine stacks the per-client matrices on a leading K axis and applies
them inside its jitted step (``project_stacked``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Path = Tuple[str, ...]


@dataclass(frozen=True)
class AxisSeg:
    """One widened axis of a union-shaped leaf: ``ids[j]`` labels the
    client coordinate union index ``j`` duplicates (equal ids = one
    segment). ``out_role`` marks the Net2Net *split* side (outgoing
    weights divided by the group size)."""
    axis: int
    ids: np.ndarray
    out_role: bool = False

    @property
    def counts(self) -> np.ndarray:
        """Per-position segment sizes c_j (length = union extent)."""
        _, inv, cnt = np.unique(np.asarray(self.ids), return_inverse=True,
                                return_counts=True)
        return cnt[inv].astype(np.int32)


def _same(seg: AxisSeg) -> np.ndarray:
    ids = np.asarray(seg.ids)
    return (ids[:, None] == ids[None, :]).astype(np.float32)


def grad_matrix(seg: AxisSeg) -> np.ndarray:
    """Axis factor of ``E Eᵀ``: segment-sum, with 1/c² on split axes
    (E = D diag(1/c) there, so E Eᵀ = D diag(1/c²) Dᵀ)."""
    b = _same(seg)
    if not seg.out_role:
        return b
    r = 1.0 / seg.counts.astype(np.float32)
    return b * r[:, None] * r[None, :]


def mean_matrix(seg: AxisSeg) -> np.ndarray:
    """Axis factor of the orthogonal projector onto image(E): the
    segment mean ``P[v, u] = [same segment] / c_v`` — identical for both
    axis roles (``E (EᵀE)⁻¹ Eᵀ = D diag(1/c) Dᵀ`` either way)."""
    return _same(seg) / seg.counts.astype(np.float32)[:, None]


# ------------------------------------------------------------- tree plumbing

def path_keys(path) -> Path:
    """jax tree_util key path -> plain string tuple."""
    return tuple(str(getattr(p, "key", p)) for p in path)


def path_str(path) -> str:
    return "/".join(path_keys(path))


def leaf_shape(shapes, path: Path):
    node = shapes
    for k in path:
        node = node[k]
    return tuple(node.shape)


def union_axes(specs: Sequence[Dict[Path, List[AxisSeg]]],
               shapes) -> Dict[Path, Tuple[int, ...]]:
    """Union over clients of (leaf path -> widened axes), axes
    canonicalized to non-negative leaf axes — the seed-invariant static
    structure the engine's jitted step closes over."""
    out: Dict[Path, set] = {}
    for spec in specs:
        for path, segs in spec.items():
            nd = len(leaf_shape(shapes, path))
            out.setdefault(path, set()).update(s.axis % nd for s in segs)
    return {p: tuple(sorted(a)) for p, a in sorted(out.items())}


def client_matrices(spec: Dict[Path, List[AxisSeg]],
                    axes_map: Dict[Path, Tuple[int, ...]], shapes, *,
                    kind: str = "grad") -> Dict[Path, List[np.ndarray]]:
    """Per-leaf, per-axis matrices for one client, aligned with the
    cohort's ``axes_map``; identity where this client has no widening
    (so every client shares one static structure and the matrices stack
    on a leading K axis)."""
    build = grad_matrix if kind == "grad" else mean_matrix
    out: Dict[Path, List[np.ndarray]] = {}
    for path, axes in axes_map.items():
        shape = leaf_shape(shapes, path)
        by_axis = {s.axis % len(shape): s for s in spec.get(path, [])}
        mats = []
        for ax in axes:
            s = by_axis.get(ax)
            mats.append(np.eye(shape[ax], dtype=np.float32) if s is None
                        else build(s))
        out[path] = mats
    return out


def stack_matrices(per_client: Sequence[Dict[Path, List[np.ndarray]]]
                   ) -> Dict[str, List[jnp.ndarray]]:
    """Stack aligned per-client matrix dicts into the ``{path-str:
    [(K, U, U), ...]}`` pytree the jitted step consumes."""
    if not per_client:
        return {}
    out: Dict[str, List[jnp.ndarray]] = {}
    for path in per_client[0]:
        out["/".join(path)] = [
            jnp.asarray(np.stack([c[path][i] for c in per_client]))
            for i in range(len(per_client[0][path]))]
    return out


def apply_leaf(x, axes: Tuple[int, ...], mats: Sequence, *, stacked: bool):
    """Apply per-axis matrices ``out[v] = Σ_u M[v,u] x[u]`` along each
    widened axis. ``stacked`` marks a leading K axis on ``x`` (and on
    every matrix)."""
    out = x.astype(jnp.float32)
    for ax, m in zip(axes, mats):
        a = ax + 1 if stacked else ax
        moved = jnp.moveaxis(out, a, -1)
        eq = "kvu,k...u->k...v" if stacked else "vu,...u->...v"
        moved = jnp.einsum(eq, m, moved)
        out = jnp.moveaxis(moved, -1, a)
    return out.astype(x.dtype)


def project_stacked(tree, axes_map: Dict[str, Tuple[int, ...]],
                    mats: Dict[str, List[jnp.ndarray]]):
    """Apply the stacked per-client segment operators to a stacked tree
    (no-op on leaves without widened axes). Used on gradients inside the
    engine's step: masks handle depth, this handles width."""
    if not axes_map:
        return tree

    def fix(path, g):
        axes = axes_map.get(path_str(path))
        if not axes:
            return g
        return apply_leaf(g, axes, mats[path_str(path)], stacked=True)

    return jax.tree_util.tree_map_with_path(fix, tree)


def project_client(tree, spec: Dict[Path, List[AxisSeg]], *,
                   kind: str = "mean"):
    """Apply one client's segment operator (mean projector by default)
    to an un-stacked union-shaped tree — the reference/test-side
    counterpart of ``project_stacked``."""

    def fix(path, g):
        segs = spec.get(path_keys(path))
        if not segs:
            return g
        build = grad_matrix if kind == "grad" else mean_matrix
        nd = g.ndim
        return apply_leaf(g, tuple(s.axis % nd for s in segs),
                          [jnp.asarray(build(s)) for s in segs],
                          stacked=False)

    return jax.tree_util.tree_map_with_path(fix, tree)


def multiplicity_tree(spec: Dict[Path, List[AxisSeg]], shapes):
    """Per-coordinate duplication counts m_kj of one client's embedding:
    the product over widened axes of the segment size (1 everywhere for
    depth-only embeddings). Feeds the multiplicity-aware coverage
    average (``core.aggregation``)."""

    def build(path, s):
        arr = np.ones(s.shape, np.float32)
        for seg in spec.get(path_keys(path), []):
            shape = [1] * len(s.shape)
            shape[seg.axis % len(s.shape)] = -1
            arr = arr * seg.counts.astype(np.float32).reshape(shape)
        return jnp.asarray(arr)

    return jax.tree_util.tree_map_with_path(build, shapes)
