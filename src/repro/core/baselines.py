"""Baselines from the paper's evaluation (Section IV.A.3).

  * Standalone    — purely local training, no aggregation.
  * Clustered-FL  — clients clustered by identical architecture; FedAvg
    within each cluster (Sattler et al., model-agnostic clustering keyed
    here on architecture identity, the setting the paper evaluates).
  * FlexiFed (Clustered-Common) — the longest common PREFIX of layers
    (identical shape, scanning the sequential chain from the input) is
    aggregated across ALL clients; the remaining (personalized) layers are
    aggregated within same-architecture clusters.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.aggregation import client_weights, fedavg


def _cluster_ids(cfgs) -> Dict[str, List[int]]:
    out: Dict[str, List[int]] = defaultdict(list)
    for i, c in enumerate(cfgs):
        out[c.name].append(i)
    return dict(out)


class Standalone:
    def __init__(self, client_cfgs, n_samples):
        self.client_cfgs = list(client_cfgs)

    def round(self, client_params: List, local_train: Callable, round_idx: int):
        return [local_train(k, p) for k, p in enumerate(client_params)]


class ClusteredFL:
    def __init__(self, client_cfgs, n_samples):
        self.client_cfgs = list(client_cfgs)
        self.n_samples = np.asarray(n_samples, np.float64)
        self.clusters = _cluster_ids(self.client_cfgs)

    def round(self, client_params: List, local_train: Callable, round_idx: int):
        new = [local_train(k, p) for k, p in enumerate(client_params)]
        for ids in self.clusters.values():
            w = client_weights(self.n_samples[ids])
            agg = fedavg([new[i] for i in ids], w)
            for i in ids:
                new[i] = agg
        return new


class FlexiFed:
    """Clustered-Common strategy. ``chain_fn(cfg, params)`` must return the
    ordered list of (layer-id, leaf-paths) pairs of the sequential chain."""

    def __init__(self, client_cfgs, n_samples, chain_fn):
        self.client_cfgs = list(client_cfgs)
        self.n_samples = np.asarray(n_samples, np.float64)
        self.clusters = _cluster_ids(self.client_cfgs)
        self.chain_fn = chain_fn

    def _common_prefix(self, client_params) -> List:
        chains = [self.chain_fn(cfg, p)
                  for cfg, p in zip(self.client_cfgs, client_params)]
        common = []
        for pos in range(min(len(c) for c in chains)):
            ids = {c[pos][0] for c in chains}
            shapes0 = [l.shape for l in jax.tree.leaves(chains[0][pos][1])]
            same_shape = all(
                [l.shape for l in jax.tree.leaves(c[pos][1])] == shapes0
                for c in chains)
            if len(ids) == 1 and same_shape:
                common.append(pos)
            else:
                break
        return common

    def round(self, client_params: List, local_train: Callable, round_idx: int):
        new = [local_train(k, p) for k, p in enumerate(client_params)]
        chains = [self.chain_fn(cfg, p)
                  for cfg, p in zip(self.client_cfgs, new)]
        common = self._common_prefix(new)
        # aggregate the common prefix across ALL clients
        w_all = client_weights(self.n_samples)
        for pos in common:
            agg = fedavg([chains[i][pos][1] for i in range(len(new))], w_all)
            for i in range(len(new)):
                _assign(chains[i][pos][1], agg)
        # aggregate the personalized remainder within clusters
        for ids in self.clusters.values():
            w = client_weights(self.n_samples[ids])
            for pos in range(len(common), len(chains[ids[0]])):
                agg = fedavg([chains[i][pos][1] for i in ids], w)
                for i in ids:
                    _assign(chains[i][pos][1], agg)
        return new


def _assign(container: Dict, values: Dict):
    for k, v in values.items():
        container[k] = v


def vgg_chain(cfg, params) -> List:
    """Sequential chain for the VGG family (layer-id, param-dict). The ids
    and tree paths come from ``VGGFamily.chain_paths`` — the single source
    the unified engine's FlexiFed grouping also uses, so the two cannot
    drift."""
    from repro.core.family import VGGFamily
    out = []
    for lid, path in VGGFamily().chain_paths(cfg):
        node = params
        for key in path:
            node = node[key]
        out.append((lid, node))
    return out
