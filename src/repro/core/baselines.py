"""Baselines from the paper's evaluation (Section IV.A.3).

  * Standalone    — purely local training, no aggregation.
  * Clustered-FL  — clients clustered by identical architecture; FedAvg
    within each cluster (Sattler et al., model-agnostic clustering keyed
    here on architecture identity, the setting the paper evaluates).
  * FlexiFed (Clustered-Common) — the longest common PREFIX of layers
    (identical shape, scanning the sequential chain from the input) is
    aggregated across ALL clients; the remaining (personalized) layers are
    aggregated within same-architecture clusters.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.aggregation import fedavg, subset_weights


def _cluster_ids(cfgs) -> Dict[str, List[int]]:
    out: Dict[str, List[int]] = defaultdict(list)
    for i, c in enumerate(cfgs):
        out[c.name].append(i)
    return dict(out)


def _resolve_selected(selected, n: int) -> List[int]:
    return list(selected if selected is not None else range(n))


class Standalone:
    def __init__(self, client_cfgs, n_samples):
        self.client_cfgs = list(client_cfgs)

    def aggregate(self, client_params: List,
                  selected: Optional[Sequence[int]] = None) -> List:
        return list(client_params)

    def round(self, client_params: List, local_train: Callable, round_idx: int):
        return [local_train(k, p) for k, p in enumerate(client_params)]


class ClusteredFL:
    def __init__(self, client_cfgs, n_samples):
        self.client_cfgs = list(client_cfgs)
        self.n_samples = np.asarray(n_samples, np.float64)
        self.clusters = _cluster_ids(self.client_cfgs)

    def aggregate(self, client_params: List,
                  selected: Optional[Sequence[int]] = None) -> List:
        """FedAvg within each (architecture cluster ∩ selected); clients
        outside ``selected`` keep their parameters untouched."""
        sel = set(_resolve_selected(selected, len(client_params)))
        new = list(client_params)
        for ids in self.clusters.values():
            ids = [i for i in ids if i in sel]
            if not ids:
                continue
            agg = fedavg([new[i] for i in ids],
                         subset_weights(self.n_samples, ids))
            for i in ids:
                new[i] = agg
        return new

    def round(self, client_params: List, local_train: Callable, round_idx: int):
        return self.aggregate(
            [local_train(k, p) for k, p in enumerate(client_params)])


class FlexiFed:
    """Clustered-Common strategy. ``chain_fn(cfg, params)`` must return the
    ordered list of (layer-id, leaf-paths) pairs of the sequential chain."""

    def __init__(self, client_cfgs, n_samples, chain_fn):
        self.client_cfgs = list(client_cfgs)
        self.n_samples = np.asarray(n_samples, np.float64)
        self.clusters = _cluster_ids(self.client_cfgs)
        self.chain_fn = chain_fn

    def _chains(self, client_params, ids: Sequence[int]) -> Dict[int, List]:
        return {i: self.chain_fn(self.client_cfgs[i], client_params[i])
                for i in ids}

    def _common_of(self, chains: Dict[int, List]) -> List:
        ordered = list(chains.values())
        common = []
        for pos in range(min(len(c) for c in ordered)):
            ids = {c[pos][0] for c in ordered}
            shapes0 = [l.shape for l in jax.tree.leaves(ordered[0][pos][1])]
            same_shape = all(
                [l.shape for l in jax.tree.leaves(c[pos][1])] == shapes0
                for c in ordered)
            if len(ids) == 1 and same_shape:
                common.append(pos)
            else:
                break
        return common

    def _common_prefix(self, client_params) -> List:
        return self._common_of(
            self._chains(client_params, range(len(client_params))))

    def aggregate(self, client_params: List,
                  selected: Optional[Sequence[int]] = None) -> List:
        """Clustered-Common over the participating subset: the common
        prefix of the SELECTED clients' chains is averaged across all of
        them, the remainder within (cluster ∩ selected). Non-participants
        are untouched. NOTE: mutates the selected entries' param dicts in
        place (through the chain views) and returns the list."""
        sel = _resolve_selected(selected, len(client_params))
        new = list(client_params)
        chains = self._chains(new, sel)
        common = self._common_of(chains)
        w_all = subset_weights(self.n_samples, sel)
        for pos in common:
            agg = fedavg([chains[i][pos][1] for i in sel], w_all)
            for i in sel:
                _assign(chains[i][pos][1], agg)
        # aggregate the personalized remainder within clusters
        for ids in self.clusters.values():
            ids = [i for i in ids if i in set(sel)]
            if not ids:
                continue
            w = subset_weights(self.n_samples, ids)
            for pos in range(len(common), len(chains[ids[0]])):
                agg = fedavg([chains[i][pos][1] for i in ids], w)
                for i in ids:
                    _assign(chains[i][pos][1], agg)
        return new

    def round(self, client_params: List, local_train: Callable, round_idx: int):
        return self.aggregate(
            [local_train(k, p) for k, p in enumerate(client_params)])


def _assign(container: Dict, values: Dict):
    for k, v in values.items():
        container[k] = v


def vgg_chain(cfg, params) -> List:
    """Sequential chain for the VGG family (layer-id, param-dict). The ids
    and tree paths come from ``VGGFamily.chain_paths`` — the single source
    the unified engine's FlexiFed grouping also uses, so the two cannot
    drift."""
    from repro.core.family import VGGFamily
    out = []
    for lid, path in VGGFamily().chain_paths(cfg):
        node = params
        for key in path:
            node = node[key]
        out.append((lid, node))
    return out
