"""FedADP core: the paper's contribution as composable JAX modules."""
from repro.core.aggregation import (  # noqa: F401
    client_weights, fedavg, fedavg_stacked, stack_trees)
from repro.core.fedadp import FedADP  # noqa: F401
from repro.core.baselines import ClusteredFL, FlexiFed, Standalone, vgg_chain  # noqa: F401
from repro.core.family import TransformerFamily, VGGFamily  # noqa: F401
