"""FedADP core: the paper's contribution as composable JAX modules."""
from repro.core.aggregation import (  # noqa: F401
    AGG_MODES, COVERAGE_POLICIES, client_weights, coverage_and_filler,
    coverage_mask, fedavg, fedavg_masked, fedavg_stacked, loosen,
    multiplicity, stack_trees, subset_weights)
from repro.core.plane import (  # noqa: F401
    PlaneSpec, cohort_planes, pack, pack_stacked, pack_trees,
    ragged_leaf_error, requantize, unpack, unpack_stacked)
from repro.core.netchange import (  # noqa: F401
    KeyedCache, NARROW_MODES, round_embed_seed)
from repro.core.quant import (  # noqa: F401
    WIRE_FORMATS, dequantize, payload_nbytes, quantize, wire_itemsize)
from repro.core.fedadp import FedADP  # noqa: F401
from repro.core.baselines import ClusteredFL, FlexiFed, Standalone, vgg_chain  # noqa: F401
from repro.core.family import TransformerFamily, VGGFamily  # noqa: F401
