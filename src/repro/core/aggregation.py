"""Model aggregation (paper Eq. 1-2) and coverage semantics — the ONE
place in the tree where "which coordinates does a client cover, and how
do covered coordinates average" is defined.

Two layouts in, ONE implementation underneath:
  * list-of-trees   — server-side aggregation of K client pytrees,
  * stacked tree    — every leaf has a leading K axis (the unified-space
                      simulation layout).
Both route through the packed parameter plane (``core.plane``): the
stacked tree packs into one contiguous ``(K, P)`` f32 plane and the
whole model aggregates in a single fused kernel pass
(``kernels/fedavg.plane_agg`` — Pallas on TPU, jnp oracle elsewhere,
selected automatically when ``use_kernel=None``), coverage masks /
multiplicity / fallback riding the same pass as row/column-aligned
planes. ``layout="leaf"`` keeps the per-leaf dispatch as the
tree-shaped reference the plane path is pinned against
(tests/test_plane.py, 1e-6).

Coverage (HeteroFL, Diao et al. 2021; survey Fan et al. 2023): FedADP's
Eq. 1-2 averages in the *unified* space, so every coordinate a client
doesn't own contributes filler (zeros / identity-conv taps) to the
average. ``coverage_mask`` defines which coordinates count as covered —
one policy, two readings:

  * ``"strict"``  — ``|up(ones) - up(zeros)| > 0``: exactly where a
                    client parameter lands; filler constants (identity
                    -conv taps) are NOT covered. This is the trainable
                    -coordinate mask the unified engine projects
                    gradients with.
  * ``"loose"``   — ``|up(ones)| > 0``: additionally counts the nonzero
                    filler constants (identity-conv center taps) as
                    covered — the loop reference's historical reading
                    (``loosen`` derives it from the strict mask + filler
                    without re-running ``up``).

``fedavg_masked`` / ``fedavg_stacked(..., masks=)`` implement the
coverage-weighted average: per coordinate, only the covering clients
contribute, with their weights renormalized over the covering subset
(``renorm=True``); coordinates no client covers take ``fallback``.
"""
from __future__ import annotations

import functools
import logging
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plane
from repro.core.segments import path_keys as sg_path_keys

COVERAGE_POLICIES = ("loose", "strict")
AGG_MODES = ("filler", "coverage")

_log = logging.getLogger("repro.core.aggregation")


def client_weights(n_samples: Sequence[int]) -> np.ndarray:
    """W_k = n_k / n  (paper Eq. 2)."""
    n = np.asarray(n_samples, np.float64)
    return (n / n.sum()).astype(np.float32)


def subset_weights(n_samples: Sequence[int],
                   selected: Optional[Sequence[int]] = None) -> np.ndarray:
    """W_k renormalized over the participating subset (Eq. 2 on the
    subset) — the single definition every partial-participation path
    (loop strategies, baselines, unified engine) shares."""
    n = np.asarray(n_samples, np.float64)
    if selected is not None:
        n = n[np.asarray(list(selected))]
    return (n / n.sum()).astype(np.float32)


# ------------------------------------------------------------- coverage
def _mask01(tree):
    return jax.tree.map(lambda a: (jnp.abs(a) > 0).astype(jnp.float32), tree)


def _client_fill(family, client_cfg, value: float):
    """A constant client-shaped tree WITHOUT running the (random) init:
    ``eval_shape`` gives the structure, the fill is free."""
    shapes = jax.eval_shape(lambda k: family.init(k, client_cfg),
                            jax.random.PRNGKey(0))
    return jax.tree.map(lambda s: jnp.full(s.shape, value, s.dtype), shapes)


def coverage_and_filler(family, client_cfg, global_cfg, *, seed: int = 0):
    """(strict coverage mask, filler) for embedding one client.

    ``up()`` is linear in the client parameters except for the constants
    it inserts (identity convs / zero blocks), so pushing an all-ones and
    an all-zeros tree through it separates the two:

      filler = up(zeros)                  — the inserted constants,
      strict = |up(ones) - up(zeros)| > 0 — 1 exactly where a client
                                            parameter lands.
    """
    up0 = family.up(_client_fill(family, client_cfg, 0.0), client_cfg,
                    global_cfg, seed=seed)
    up1 = family.up(_client_fill(family, client_cfg, 1.0), client_cfg,
                    global_cfg, seed=seed)
    strict = jax.tree.map(
        lambda a, b: (jnp.abs(a - b) > 0).astype(jnp.float32), up1, up0)
    return strict, up0


def loosen(strict_mask, filler):
    """loose = strict ∪ nonzero-filler sites: parameter landing sites and
    filler constants are disjoint by construction (To-Deeper inserts whole
    constant layers, To-Wider only duplicates client parameters), so the
    loose reading is exactly ``|up(ones)| > 0``."""
    return jax.tree.map(
        lambda m, f: jnp.maximum(m, (jnp.abs(f) > 0).astype(m.dtype)),
        strict_mask, filler)


_SHAPE_MEMO: dict = {}


def global_shapes(family, global_cfg):
    """``jax.eval_shape`` of the family's init at ``global_cfg``, memoized
    per (family type, config) — configs are frozen/hashable, shapes never
    change, and the seed-keyed callers (per-round multiplicity, segment
    specs) would otherwise re-trace the full model every round."""
    key = (type(family).__name__, global_cfg)
    if key not in _SHAPE_MEMO:
        _SHAPE_MEMO[key] = jax.eval_shape(
            lambda k: family.init(k, global_cfg), jax.random.PRNGKey(0))
        while len(_SHAPE_MEMO) > 64:
            _SHAPE_MEMO.pop(next(iter(_SHAPE_MEMO)))
    return _SHAPE_MEMO[key]


def multiplicity(family, client_cfg, global_cfg, *, seed: int = 0):
    """Per-coordinate duplication counts m_kj of a client's width
    embedding (1 everywhere for depth-only embeddings): how many union
    coordinates share the client coordinate that lands on j, derived
    from the family's ``segment_spec``. The multiplicity-aware coverage
    average weights client k's contribution at j by ``W_k m_kj⁻¹`` so a
    To-Wider-duplicated channel's total weight stays W_k instead of
    scaling with its copy count. Families without segment metadata fall
    back to all-ones (plain 0/1-mask semantics)."""
    from repro.core import segments as sg
    shapes = global_shapes(family, global_cfg)
    spec_fn = getattr(family, "segment_spec", None)
    spec = spec_fn(client_cfg, global_cfg, seed=seed) if spec_fn else {}
    return sg.multiplicity_tree(spec, shapes)


def coverage_mask(family, client_cfg, global_cfg, *,
                  policy: str = "strict", seed: int = 0):
    """Global-space 0/1 mask of the coordinates a client covers, under
    the given policy (module docstring). "loose" is a single ``up(ones)``
    push (matching the per-round cost of the loop reference it encodes);
    "strict" needs the second ``up(zeros)`` push to cancel the filler."""
    if policy not in COVERAGE_POLICIES:
        raise ValueError(
            f"coverage policy={policy!r}, expected one of {COVERAGE_POLICIES}")
    if policy == "loose":
        return _mask01(family.up(_client_fill(family, client_cfg, 1.0),
                                 client_cfg, global_cfg, seed=seed))
    strict, _ = coverage_and_filler(family, client_cfg, global_cfg, seed=seed)
    return strict


# ---------------------------------------------------------- aggregation
AGG_LAYOUTS = ("plane", "stream", "leaf")

# "stream" once the materialized cohort plane would cross this (or K
# grows past _AUTO_STREAM_K): past here the O(P·K_chunk) accumulator
# beats holding (K, P) + the kernel's temporaries resident
_AUTO_STREAM_K = 32
_AUTO_STREAM_BYTES = 256 * 2 ** 20
_auto_logged: set = set()


def resolve_agg_layout(layout: Optional[str], *, backend: Optional[str] = None,
                       k: Optional[int] = None, p: Optional[int] = None,
                       k_chunk: Optional[int] = None) -> str:
    """The ONE ``agg_layout="auto"`` rule. Explicit layouts pass through
    (validated against ``AGG_LAYOUTS``); ``"auto"``/``None`` picks from
    the backend and cohort shape:

      * ``"stream"`` when the caller pinned a ``k_chunk``, or the cohort
        plane is large (K > 32 or K·P·4 bytes > 256 MiB) — the streaming
        accumulator's O(P·K_chunk) memory envelope,
      * ``"plane"`` otherwise — the whole-plane fused pass, fastest at
        small K on every backend (BENCH_new.json),
      * ``"leaf"`` is NEVER auto-selected: it is the per-leaf reference
        dispatch, kept only for pinning tests and benchmarks.

    The decision is logged once per distinct (backend, choice) so runs
    are diagnosable without log spam, and is overridable everywhere the
    knob appears (``FLRunConfig.agg_layout``, strategy, engine).
    """
    if layout in AGG_LAYOUTS:
        return layout
    if layout not in (None, "auto"):
        raise ValueError(f"agg_layout={layout!r}, expected 'auto' or one "
                         f"of {AGG_LAYOUTS}")
    if backend is None:
        backend = jax.default_backend()
    big = (k is not None and k > _AUTO_STREAM_K) or (
        k is not None and p is not None
        and 4 * k * p > _AUTO_STREAM_BYTES)
    choice = "stream" if (k_chunk is not None or big) else "plane"
    key = (backend, choice)
    if key not in _auto_logged:
        _auto_logged.add(key)
        _log.info("agg_layout='auto' -> %r (backend=%s, K=%s, P=%s, "
                  "k_chunk=%s)", choice, backend, k, p, k_chunk)
    return choice


_last_stats: dict = {}


def last_agg_stats() -> dict:
    """Stats of the most recent ``fedavg_stacked`` call on this process:
    ``layout``, ``k_chunk`` (streaming only), ``rows``/``n`` (cohort
    shape) and ``peak_bytes`` — the resident aggregation footprint
    (whole ``4·K·P`` plane for "plane"/"leaf"; the accumulator triple
    plus one ``4·k_chunk·P`` chunk for "stream",
    ``PlaneAccumulator.stats``). Diagnostic surface for benchmarks
    (``unified_bench``'s peak-memory column) — not part of the math."""
    return dict(_last_stats)


def _record_stats(**kw) -> None:
    _last_stats.clear()
    _last_stats.update(kw)


def default_k_chunk(k: int, k_chunk: Optional[int] = None) -> int:
    """The streaming chunk size: the caller's pin, else 16 rows (a chunk
    small enough that three accumulator buffers + one chunk undercut the
    whole plane from K = 64 up, large enough to amortize dispatch)."""
    return max(1, min(k_chunk if k_chunk is not None else 16, k))


def fedavg(trees: Sequence, weights, *, layout: Optional[str] = None,
           k_chunk: Optional[int] = None) -> object:
    """omega^{t+1} = sum_k W_k omega_k  (paper Eq. 1) — ONE
    implementation: stack + a single packed-plane pass (the old
    per-leaf Python accumulate loop, with its per-client f32
    round-trip, is gone)."""
    w = jnp.asarray(weights, jnp.float32)
    assert len(trees) == w.shape[0]
    return fedavg_stacked(stack_trees(trees), w, layout=layout,
                          k_chunk=k_chunk)


@functools.partial(jax.jit,
                   static_argnames=("spec", "renorm", "use_kernel"))
def _plane_pass(stacked, w, masks, mult, fallback, *, spec,
                renorm: bool, use_kernel: bool):
    """The whole aggregation as ONE jitted program keyed on the static
    ``PlaneSpec``: pack (reshape/concat — fused away by XLA), one
    ``plane_agg`` kernel dispatch, unpack (slice/reshape + dtype
    restore). ``masks``/``mult``/``fallback`` may be ``None``."""
    from repro.kernels.fedavg import ops as kops
    x = plane.pack_stacked(stacked, spec, what="fedavg_stacked")
    m = (plane.pack_stacked(masks, spec, what="fedavg_stacked/masks")
         if masks is not None else None)
    mu = (plane.pack_stacked(mult, spec, what="fedavg_stacked/mult")
          if mult is not None else None)
    fb = (plane.pack(fallback, spec, what="fedavg_stacked/fallback")
          if fallback is not None else None)
    out = kops.plane_agg(x, w, masks=m, mult=mu, fallback=fb,
                         renorm=renorm, use_kernel=use_kernel)
    return plane.unpack(out, spec)


def fedavg_stacked(stacked, weights, *, masks=None, mult=None,
                   renorm: bool = True, fallback=None,
                   use_kernel: Optional[bool] = None,
                   layout: Optional[str] = None,
                   k_chunk: Optional[int] = None):
    """Aggregate a stacked tree: every leaf (K, ...) -> (...).

    Without ``masks`` this is Eq. 1 verbatim. With ``masks`` (a stacked
    0/1 tree of the same shape) it is the coverage-weighted average: per
    coordinate only covering clients contribute, their weights
    renormalized over the covering subset when ``renorm``; coordinates no
    client covers take the matching ``fallback`` leaf (or 0). With
    ``mult`` (a stacked tree of per-coordinate duplication counts, see
    ``multiplicity``) the per-coordinate client weight becomes
    ``W_k m_k / mult_k`` — the multiplicity-aware average for width
    embeddings, fused into the same kernel pass.

    ``layout=None``/"auto" resolves per ``resolve_agg_layout``: "plane"
    packs the whole tree into one ``(K, P)`` plane and aggregates in a
    single fused kernel dispatch (``core.plane`` +
    ``kernels/fedavg.plane_agg``); "stream" consumes the cohort in
    ``(k_chunk, P)`` row chunks through a :class:`PlaneAccumulator`, so
    no more than one chunk plus three ``(P,)`` buffers is ever resident
    — identical math (accumulate + one divide), O(P·k_chunk) memory;
    "leaf" is the per-leaf reference dispatch the plane path is pinned
    against. ``use_kernel=None`` auto-selects the Pallas kernel
    (compiled) on a TPU backend and the jnp fallback everywhere else.
    Masks/mult/fallback trees are validated leaf-by-leaf — a structure
    or shape mismatch raises naming the offending leaf path.
    """
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        from repro.kernels.fedavg.fedavg import on_tpu
        use_kernel = on_tpu()
    if mult is not None:
        assert masks is not None, "mult needs masks (coverage aggregation)"
    spec, _ = plane.PlaneSpec.from_stacked(stacked)
    layout = resolve_agg_layout(layout, k=int(w.shape[0]), p=spec.size,
                                k_chunk=k_chunk)
    if layout == "plane":
        _record_stats(layout="plane", k_chunk=None, rows=int(w.shape[0]),
                      n=spec.size, peak_bytes=4 * int(w.shape[0]) * spec.size)
        return _plane_pass(stacked, w, masks, mult, fallback, spec=spec,
                           renorm=renorm, use_kernel=bool(use_kernel))
    if layout == "stream":
        return _stream_pass(
            stacked, w, masks, mult, fallback, spec=spec, renorm=renorm,
            use_kernel=bool(use_kernel),
            k_chunk=default_k_chunk(int(w.shape[0]), k_chunk))
    _record_stats(layout="leaf", k_chunk=None, rows=int(w.shape[0]),
                  n=spec.size, peak_bytes=4 * int(w.shape[0]) * spec.size)
    return _fedavg_stacked_leaf(stacked, w, masks=masks, mult=mult,
                                renorm=renorm, fallback=fallback,
                                use_kernel=use_kernel)


def _stream_pass(stacked, w, masks, mult, fallback, *, spec,
                 renorm: bool, use_kernel: bool, k_chunk: int):
    """The streaming realization of ``fedavg_stacked``: pack each
    ``k_chunk``-row slice on its own (``plane.stacked_rows`` +
    ``pack_stacked``), stream it into a :class:`PlaneAccumulator`
    (donated buffers, one jitted step per chunk), and close with the
    single divide/fallback pass — never more than one ``(k_chunk, P)``
    chunk resident. Equals ``_plane_pass`` to 1e-6 (the accumulate is
    the same masked weighted sum, split associatively)."""
    from repro.kernels.fedavg import ops as kops
    acc = kops.PlaneAccumulator(spec.size, use_kernel=use_kernel,
                                k_hint=k_chunk)
    for lo, hi in plane.chunk_bounds(int(w.shape[0]), k_chunk):
        x = plane.pack_stacked(plane.stacked_rows(stacked, lo, hi), spec,
                               what="fedavg_stacked/stream")
        m = (plane.pack_stacked(plane.stacked_rows(masks, lo, hi), spec,
                                what="fedavg_stacked/stream-masks")
             if masks is not None else None)
        mu = (plane.pack_stacked(plane.stacked_rows(mult, lo, hi), spec,
                                 what="fedavg_stacked/stream-mult")
              if mult is not None else None)
        acc.update(x, w[lo:hi], masks=m, mult=mu)
    fb = (plane.pack(fallback, spec, what="fedavg_stacked/fallback")
          if fallback is not None else None)
    out = acc.finish(renorm=(masks is not None and renorm), fallback=fb)
    _record_stats(layout="stream", k_chunk=k_chunk, **acc.stats())
    return plane.unpack(out, spec)


def plane_partials(x, w, masks=None, mult=None):
    """Edge-reduce unit of the two-level hierarchy, pure jnp (and hence
    ``shard_map``-able — the engine psums the triple over the cohort
    mesh): one sub-cohort's packed rows ``x (K_g, P)`` with GLOBAL subset
    weights ``w (K_g,)`` -> the partial ``(num, den, cov)`` triple,
    each ``(P,)``. Summing triples across groups and finishing once
    (``finish_partials``) equals the flat aggregation exactly — the
    masked weighted sum is associative."""
    from repro.kernels.fedavg import ref as kref
    z = jnp.zeros(x.shape[-1], jnp.float32)
    return kref.plane_accum_ref(z, z, z, x, w, masks, mult)


def finish_partials(num, den, cov, *, renorm: bool = True, fallback=None):
    """Global reduce tail: close summed ``(P,)`` partial triples with the
    one divide/fallback pass (``ref.plane_finish_ref``)."""
    from repro.kernels.fedavg import ref as kref
    return kref.plane_finish_ref(num, den, cov, fallback, renorm=renorm)


def fedavg_hierarchical(stacked, weights, *, groups, masks=None, mult=None,
                        renorm: bool = True, fallback=None,
                        use_kernel: Optional[bool] = None,
                        k_chunk: Optional[int] = None):
    """Two-level hierarchical aggregation: ``groups`` (a partition of
    ``range(K)`` into edge sub-cohorts, any sizes/order) each stream
    their rows into their OWN :class:`PlaneAccumulator` (the edge
    reduce), the partial triples merge by summation (the global reduce),
    and ONE finish pass closes — exact vs. the flat aggregation by
    associativity, for every split. Weights are the GLOBAL subset
    weights throughout; per-group renormalization would be wrong and is
    never applied. ``masks``/``mult``/``fallback``/``renorm`` follow
    ``fedavg_stacked``."""
    w = jnp.asarray(weights, jnp.float32)
    K = int(w.shape[0])
    flat_idx = sorted(int(i) for g in groups for i in g)
    if flat_idx != list(range(K)):
        raise ValueError(
            f"groups must partition range({K}) exactly, got {groups!r}")
    if mult is not None:
        assert masks is not None, "mult needs masks (coverage aggregation)"
    if use_kernel is None:
        from repro.kernels.fedavg.fedavg import on_tpu
        use_kernel = on_tpu()
    from repro.kernels.fedavg import ops as kops
    spec, _ = plane.PlaneSpec.from_stacked(stacked)
    kc = default_k_chunk(K, k_chunk)

    def packed_rows(tree, sel, what):
        rows = jax.tree.map(lambda a: a[sel], tree)
        return plane.pack_stacked(rows, spec, what=what)

    total = None
    for g in groups:
        idx = np.asarray(list(g), np.int32)
        acc = kops.PlaneAccumulator(spec.size, use_kernel=bool(use_kernel),
                                    k_hint=kc)
        for lo in range(0, idx.size, kc):
            sel = idx[lo:lo + kc]
            acc.update(
                packed_rows(stacked, sel, "fedavg_hierarchical"),
                w[sel],
                masks=(packed_rows(masks, sel, "fedavg_hierarchical/masks")
                       if masks is not None else None),
                mult=(packed_rows(mult, sel, "fedavg_hierarchical/mult")
                      if mult is not None else None))
        total = acc if total is None else total.merge(acc)
    fb = (plane.pack(fallback, spec, what="fedavg_hierarchical/fallback")
          if fallback is not None else None)
    out = total.finish(renorm=(masks is not None and renorm), fallback=fb)
    return plane.unpack(out, spec)


def _fedavg_stacked_leaf(stacked, w, *, masks, mult, renorm, fallback,
                         use_kernel):
    """Per-leaf reference dispatch (one kernel launch per leaf) — the
    tree-shaped semantics the packed plane path must reproduce to 1e-6;
    kept for pinning tests and the dispatch-count benchmark
    (``benchmarks/unified_bench.py`` ``agg_layout`` rows)."""
    if masks is None:
        if use_kernel:
            from repro.kernels.fedavg import ops as kops

            def agg(leaf):
                return kops.weighted_sum(leaf, w).astype(leaf.dtype)
        else:
            def agg(leaf):
                flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
                out = jnp.einsum("k,kn->n", w, flat)
                return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

        return jax.tree.map(agg, stacked)

    if use_kernel:
        from repro.kernels.fedavg import ops as kops

        def masked(leaf, m, mu):
            return kops.weighted_sum_masked(leaf, w, m, mult=mu,
                                            renorm=renorm)
    else:
        def masked(leaf, m, mu):
            flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
            mf = m.reshape(m.shape[0], -1).astype(jnp.float32)
            wm = w[:, None] * mf
            if mu is not None:
                muf = mu.reshape(mu.shape[0], -1).astype(jnp.float32)
                wm = wm / jnp.where(muf > 0, muf, 1.0)
            num = jnp.sum(wm * flat, axis=0)
            if renorm:
                den = jnp.sum(wm, axis=0)
                num = jnp.where(den > 0,
                                num / jnp.where(den > 0, den, 1.0), 0.0)
            return num.reshape(leaf.shape[1:])

    def agg(leaf, m, mu, fb):
        out = masked(leaf, m, mu)
        if fb is not None:
            covered = jnp.any(m > 0, axis=0)
            out = jnp.where(covered, out, fb.astype(jnp.float32))
        return out.astype(leaf.dtype)

    xs, treedef = jax.tree.flatten(stacked)

    def aligned(tree, name):
        if tree is None:
            return [None] * len(xs)
        leaves, td = jax.tree.flatten(tree)
        assert td == treedef, (f"{name} tree structure does not match "
                               f"stacked: {td} vs {treedef}")
        return leaves

    return jax.tree.unflatten(treedef, [
        agg(*args) for args in zip(xs, aligned(masks, "masks"),
                                   aligned(mult, "mult"),
                                   aligned(fallback, "fallback"))])


def fedavg_masked(trees: Sequence, weights, masks: Sequence, *,
                  mult: Optional[Sequence] = None, renorm: bool = True,
                  fallback=None, use_kernel: Optional[bool] = None,
                  layout: Optional[str] = None,
                  k_chunk: Optional[int] = None):
    """List-of-trees layout of the coverage-weighted average: the
    HeteroFL rule — average each coordinate over only the clients that
    hold it (optionally multiplicity-aware via ``mult``, a list of
    per-client duplication-count trees). Delegates to ``fedavg_stacked``
    so the coverage math has exactly one implementation."""
    assert len(trees) == len(masks)
    return fedavg_stacked(stack_trees(trees), weights,
                          masks=stack_trees(masks),
                          mult=stack_trees(mult) if mult is not None else None,
                          renorm=renorm, fallback=fallback,
                          use_kernel=use_kernel, layout=layout,
                          k_chunk=k_chunk)


def stack_trees(trees: Sequence):
    """Stack K same-structure trees on a new leading axis. Ragged input
    raises ``ValueError`` naming the offending leaf path and the two
    mismatched shapes (``plane.ragged_leaf_error`` — the same message
    contract ``PlaneSpec`` uses) instead of an opaque broadcast error."""
    trees = list(trees)
    assert trees, "stack_trees: no trees"
    flat0, td0 = jax.tree_util.tree_flatten_with_path(trees[0])
    cols = [[leaf for _, leaf in flat0]]
    for i, t in enumerate(trees[1:], start=1):
        flat, td = jax.tree_util.tree_flatten_with_path(t)
        if td != td0:
            raise ValueError(
                f"stack_trees: tree {i} structure does not match tree 0: "
                f"{td} vs {td0}")
        for (path, leaf), (_, leaf0) in zip(flat, flat0):
            if tuple(leaf.shape) != tuple(leaf0.shape):
                raise plane.ragged_leaf_error(
                    f"stack_trees (tree {i} vs tree 0)",
                    sg_path_keys(path), leaf.shape, leaf0.shape)
        cols.append([leaf for _, leaf in flat])
    leaves = [jnp.stack(ls, axis=0) for ls in zip(*cols)]
    return jax.tree_util.tree_unflatten(td0, leaves)
