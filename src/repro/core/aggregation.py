"""Model aggregation (paper Eq. 1-2): weighted FedAvg in the unified space.

Two layouts:
  * list-of-trees   — server-side aggregation of K client pytrees,
  * stacked tree    — every leaf has a leading K axis (the unified-space
                      simulation layout); hot path backed by the Pallas
                      ``fedavg`` kernel on TPU (jnp fallback elsewhere,
                      selected automatically when ``use_kernel=None``).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def client_weights(n_samples: Sequence[int]) -> np.ndarray:
    """W_k = n_k / n  (paper Eq. 2)."""
    n = np.asarray(n_samples, np.float64)
    return (n / n.sum()).astype(np.float32)


def fedavg(trees: Sequence, weights) -> object:
    """omega^{t+1} = sum_k W_k omega_k  (paper Eq. 1)."""
    w = jnp.asarray(weights)
    assert len(trees) == w.shape[0]

    def agg(*leaves):
        acc = leaves[0].astype(jnp.float32) * w[0]
        for i in range(1, len(leaves)):
            acc = acc + leaves[i].astype(jnp.float32) * w[i]
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(agg, *trees)


def fedavg_stacked(stacked, weights, *, use_kernel: Optional[bool] = None):
    """Aggregate a stacked tree: every leaf (K, ...) -> (...).

    ``use_kernel=None`` auto-selects the Pallas kernel (compiled) on a TPU
    backend and the jnp einsum fallback everywhere else; pass an explicit
    bool to force either path.
    """
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        from repro.kernels.fedavg.fedavg import on_tpu
        use_kernel = on_tpu()

    if use_kernel:
        from repro.kernels.fedavg import ops as kops

        def agg(leaf):
            return kops.weighted_sum(leaf, w).astype(leaf.dtype)
    else:
        def agg(leaf):
            flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
            out = jnp.einsum("k,kn->n", w, flat)
            return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree.map(agg, stacked)


def stack_trees(trees: Sequence):
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *trees)
