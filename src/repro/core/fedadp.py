"""FedADP — Algorithm 1 of the paper.

Round t:
  1. for each selected client k:   omega_k <- NetChange(omega^t, omega_k)
     (To-Shallower + To-Narrower: server tailors the global model down)
  2. local training on client k's data
  3. omega_k <- NetChange(omega_k, omega^t)
     (To-Deeper + To-Wider: expand back to the global architecture)
  4. omega^{t+1} <- sum_k W_k omega_k   (FedAvg, Eq. 1-2)

``narrow_mode`` selects the paper's Alg. 3 ("paper") or the beyond-paper
function-preserving fold inverse ("fold") — compared in ablations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import client_weights, fedavg


@dataclass
class FedADP:
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    narrow_mode: str = "paper"
    base_seed: int = 0

    def __post_init__(self):
        self.global_cfg = self.family.union(list(self.client_cfgs))
        self.weights = client_weights(self.n_samples)

    def init_global(self, key):
        return self.family.init(key, self.global_cfg)

    def _seed(self, round_idx: int, k: int) -> int:
        # one seed per (round, client): the distribute-fold and collect-widen
        # mappings of a round are mutual inverses.
        return (self.base_seed * 1_000_003 + round_idx * 997 + k) % (2**31)

    def distribute(self, global_params, round_idx: int, k: int):
        """Step 1: NetChange(omega^t, omega_k)."""
        return self.family.down(global_params, self.global_cfg,
                                self.client_cfgs[k],
                                seed=self._seed(round_idx, k),
                                mode=self.narrow_mode)

    def collect(self, client_params, round_idx: int, k: int):
        """Step 3: NetChange(omega_k, omega^t)."""
        return self.family.up(client_params, self.client_cfgs[k],
                              self.global_cfg,
                              seed=self._seed(round_idx, k))

    def coverage_mask(self, round_idx: int, k: int, like):
        """Global-space 0/1 mask of the coordinates client k's expansion
        touches at this round: push an all-ones client tree (structured
        like ``like``) through ``collect`` and threshold. Identity-conv
        filler taps count as covered under this (loop-reference) reading —
        see ``UnifiedEngine.aggregate_global`` for the stricter one."""
        ones = jax.tree.map(jnp.ones_like, like)
        return jax.tree.map(lambda m: (jnp.abs(m) > 0).astype(jnp.float32),
                            self.collect(ones, round_idx, k))

    def aggregate(self, expanded: Sequence,
                  selected: Optional[Sequence[int]] = None):
        """Step 4 (Eq. 1-2): FedAvg of the expanded client models, with
        W_k renormalized over the participating subset."""
        selected = list(selected if selected is not None
                        else range(len(self.client_cfgs)))
        w = self.weights[np.asarray(selected)]
        return fedavg(expanded, w / w.sum())

    def round(self, global_params, local_train: Callable, round_idx: int,
              selected: Optional[Sequence[int]] = None):
        """One FedADP round. ``local_train(k, client_params)`` runs the
        client-side update and returns new client params."""
        selected = list(selected if selected is not None
                        else range(len(self.client_cfgs)))
        expanded = []
        for k in selected:
            ck = self.distribute(global_params, round_idx, k)
            ck = local_train(k, ck)
            expanded.append(self.collect(ck, round_idx, k))
        return self.aggregate(expanded, selected)
