"""FedADP — Algorithm 1 of the paper.

Round t:
  1. for each selected client k:   omega_k <- NetChange(omega^t, omega_k)
     (To-Shallower + To-Narrower: server tailors the global model down)
  2. local training on client k's data
  3. omega_k <- NetChange(omega_k, omega^t)
     (To-Deeper + To-Wider: expand back to the global architecture)
  4. omega^{t+1} <- sum_k W_k omega_k   (FedAvg, Eq. 1-2)

``narrow_mode`` selects the paper's Alg. 3 ("paper") or the beyond-paper
function-preserving fold inverse ("fold") — compared in ablations.

Coverage knobs (single-sourced in ``core.aggregation``):
  * ``coverage``  — which coordinates count as covered: "loose"
                    (``|up(ones)| > 0``, counts identity-conv filler taps)
                    or "strict" (parameter landing sites only).
  * ``agg_mode``  — "filler": Eq. 1 verbatim (the filler ``up()`` inserts
                    participates in the average); "coverage": the
                    HeteroFL-style renormalized average over covering
                    clients only, with uncovered coordinates keeping the
                    server's current values. On width-heterogeneous
                    cohorts the coverage average is multiplicity-aware:
                    client k's weight at a coordinate its embedding
                    duplicated m times is W_k/m, so a client channel's
                    total weight stays W_k regardless of copy count.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

from repro.core.aggregation import (AGG_LAYOUTS, AGG_MODES,
                                    COVERAGE_POLICIES, client_weights,
                                    coverage_mask, fedavg, fedavg_masked,
                                    multiplicity, subset_weights)
from repro.core.netchange import KeyedCache, round_embed_seed


@dataclass
class FedADP:
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    narrow_mode: str = "paper"
    coverage: str = "loose"      # the loop-reference reading
    agg_mode: str = "filler"     # the paper's Eq. 1
    base_seed: int = 0
    agg_layout: Optional[str] = None   # aggregation layout: None/"auto"
                                       # resolves per cohort shape
                                       # (aggregation.resolve_agg_layout);
                                       # "plane" | "stream" | "leaf" pin
    k_chunk: Optional[int] = None      # streaming chunk rows (None = auto)

    def __post_init__(self):
        if self.agg_layout not in (None, "auto") + AGG_LAYOUTS:
            raise ValueError(
                f"agg_layout={self.agg_layout!r}, expected None, 'auto' "
                f"or one of {AGG_LAYOUTS}")
        if self.k_chunk is not None and int(self.k_chunk) < 1:
            raise ValueError(f"k_chunk={self.k_chunk!r}, expected a "
                             f"positive int or None")
        if self.coverage not in COVERAGE_POLICIES:
            raise ValueError(f"coverage={self.coverage!r}, expected one of "
                             f"{COVERAGE_POLICIES}")
        if self.agg_mode not in AGG_MODES:
            raise ValueError(f"agg_mode={self.agg_mode!r}, expected one of "
                             f"{AGG_MODES}")
        self.global_cfg = self.family.union(list(self.client_cfgs))
        self.weights = client_weights(self.n_samples)
        # coverage masks are seed-invariant on depth-only cohorts (the
        # embedding seed only steers To-Wider duplication), so they cache
        # per (client, policy) — the seed key collapses to None; width
        # -heterogeneous masks are deterministic in the per-round seed, so
        # they cache per (client, policy, seed). ONE bounded KeyedCache
        # (shared sizing rule with the unified engine — netchange) holds
        # both mask and multiplicity entries under namespaced keys; the
        # per-round working set (≤ 2·K entries) never evicts itself.
        self._depth_only = self.family.depth_only(list(self.client_cfgs))
        self._cache = KeyedCache(n_clients=len(self.client_cfgs))

    def init_global(self, key):
        return self.family.init(key, self.global_cfg)

    def _seed(self, round_idx: int, k: int) -> int:
        # one seed per (round, client): the distribute-fold and collect-widen
        # mappings of a round are mutual inverses. Shared formula with the
        # unified engine (netchange.round_embed_seed) so both paths draw
        # identical To-Wider mappings.
        return round_embed_seed(self.base_seed, round_idx, k)

    def cache_stats(self) -> dict:
        """Hit/miss/size/bound of the embedding-artifact cache
        (``netchange.KeyedCache``)."""
        return self._cache.stats()

    def distribute(self, global_params, round_idx: int, k: int):
        """Step 1: NetChange(omega^t, omega_k)."""
        return self.family.down(global_params, self.global_cfg,
                                self.client_cfgs[k],
                                seed=self._seed(round_idx, k),
                                mode=self.narrow_mode)

    def collect(self, client_params, round_idx: int, k: int):
        """Step 3: NetChange(omega_k, omega^t)."""
        return self.family.up(client_params, self.client_cfgs[k],
                              self.global_cfg,
                              seed=self._seed(round_idx, k))

    def coverage_mask(self, round_idx: int, k: int, *,
                      policy: Optional[str] = None):
        """Global-space 0/1 mask of the coordinates client k's expansion
        covers at this round, under this instance's ``coverage`` policy
        (or an explicit override) — delegates to ``core.aggregation``,
        the single source of coverage semantics. Masks are deterministic
        in the embedding seed, so they cache per (client, policy) on
        depth-only cohorts (seed-invariant there) and per (client,
        policy, round seed) otherwise — one ``coverage_mask`` build per
        distinct seed, in a bounded LRU."""
        policy = policy or self.coverage
        seed = self._seed(round_idx, k)

        def build():
            return coverage_mask(self.family, self.client_cfgs[k],
                                 self.global_cfg, policy=policy, seed=seed)

        # depth-only: seed-invariant, so the seed key collapses to None
        # (one build per (client, policy), kept warm by every round's use)
        key = ("mask", k, policy, None if self._depth_only else seed)
        return self._cache.get(key, build)

    def coverage_multiplicity(self, round_idx: int, k: int):
        """Per-coordinate duplication counts of client k's expansion at
        this round (``aggregation.multiplicity``) — None on depth-only
        cohorts, where every count is 1. Cached like the masks."""
        if self._depth_only:
            return None
        seed = self._seed(round_idx, k)
        return self._cache.get(
            ("mult", k, seed),
            lambda: multiplicity(self.family, self.client_cfgs[k],
                                 self.global_cfg, seed=seed))

    def aggregate(self, expanded: Sequence,
                  selected: Optional[Sequence[int]] = None, *,
                  round_idx: Optional[int] = None, global_params=None):
        """Step 4 (Eq. 1-2): FedAvg of the expanded client models, with
        W_k renormalized over the participating subset.

        ``agg_mode="coverage"`` replaces Eq. 1's filler-polluted average
        with the per-coordinate renormalized average over covering
        clients; coordinates no participant covers keep ``global_params``
        (both required in that mode — the masks must match the seed the
        updates were embedded with, so the round may not be guessed).
        """
        selected = list(selected if selected is not None
                        else range(len(self.client_cfgs)))
        w = subset_weights(self.n_samples, selected)
        if self.agg_mode == "coverage":
            if global_params is None:
                raise ValueError(
                    'agg_mode="coverage" needs global_params: coordinates '
                    "no participant covers keep the server's values")
            if round_idx is None:
                raise ValueError(
                    'agg_mode="coverage" needs round_idx: the coverage '
                    "masks must use the seed the updates were embedded "
                    "with")
            masks = [self.coverage_mask(round_idx, k) for k in selected]
            mults = [self.coverage_multiplicity(round_idx, k)
                     for k in selected]
            return fedavg_masked(expanded, w, masks,
                                 mult=(None if mults[0] is None else mults),
                                 renorm=True, fallback=global_params,
                                 layout=self.agg_layout,
                                 k_chunk=self.k_chunk)
        return fedavg(expanded, w, layout=self.agg_layout,
                      k_chunk=self.k_chunk)

    def round(self, global_params, local_train: Callable, round_idx: int,
              selected: Optional[Sequence[int]] = None):
        """One FedADP round. ``local_train(k, client_params)`` runs the
        client-side update and returns new client params."""
        selected = list(selected if selected is not None
                        else range(len(self.client_cfgs)))
        expanded = []
        for k in selected:
            ck = self.distribute(global_params, round_idx, k)
            ck = local_train(k, ck)
            expanded.append(self.collect(ck, round_idx, k))
        return self.aggregate(expanded, selected, round_idx=round_idx,
                              global_params=global_params)
