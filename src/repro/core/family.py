"""Family abstraction: the architecture lattice FedADP operates over.

A *family* knows how to (a) compute the union architecture of a cohort,
(b) move parameters up (client->global) and down (global->client) with
NetChange, and (c) init/evaluate members. Two concrete families:

  * VGGFamily          — the paper's own setting (conv chains).
  * TransformerFamily  — beyond-paper: any assigned architecture config,
                         variants over depth / FFN width / experts / d_rnn.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import tfamily, vggops
from repro.configs.vgg_family import VGGConfig, union_config


@dataclass(frozen=True)
class VGGFamily:
    def union(self, cfgs: Sequence[VGGConfig]) -> VGGConfig:
        return union_config(list(cfgs))

    def init(self, key, cfg):
        from repro.models import vgg
        return vgg.init_params(key, cfg)

    def up(self, params, from_cfg, to_cfg, *, seed=0):
        return vggops.up(params, from_cfg, to_cfg, seed=seed)

    def down(self, params, from_cfg, to_cfg, *, seed=0, mode="paper"):
        return vggops.down(params, from_cfg, to_cfg, seed=seed, mode=mode)

    def loss_and_grad(self, cfg):
        from repro.models import vgg

        def f(params, batch):
            return jax.value_and_grad(vgg.loss_fn, has_aux=True)(params, cfg, batch)
        return f

    def evaluate(self, params, cfg, batch):
        from repro.models import vgg
        logits = vgg.apply(params, cfg, batch["x"])
        return float((logits.argmax(-1) == batch["y"]).mean())


@dataclass(frozen=True)
class TransformerFamily:
    def union(self, cfgs):
        return tfamily.union(list(cfgs))

    def init(self, key, cfg):
        from repro.models import transformer as T
        return T.init_params(key, cfg)

    def up(self, params, from_cfg, to_cfg, *, seed=0):
        return tfamily.up(params, from_cfg, to_cfg, seed=seed)

    def down(self, params, from_cfg, to_cfg, *, seed=0, mode="paper"):
        return tfamily.down(params, from_cfg, to_cfg, seed=seed, mode=mode)

    def loss_and_grad(self, cfg):
        from repro.launch.steps import lm_loss

        def f(params, batch):
            (loss, aux), g = jax.value_and_grad(lm_loss, has_aux=True)(
                params, cfg, batch)
            return (loss, aux), g
        return f

    def evaluate(self, params, cfg, batch):
        from repro.launch.steps import lm_loss
        loss, _ = lm_loss(params, cfg, batch)
        return float(loss)
