"""Family abstraction: the architecture lattice FedADP operates over.

A *family* knows how to (a) compute the union architecture of a cohort,
(b) move parameters up (client->global) and down (global->client) with
NetChange, and (c) init/evaluate members. Two concrete families:

  * VGGFamily          — the paper's own setting (conv chains).
  * TransformerFamily  — beyond-paper: any assigned architecture config,
                         variants over depth / FFN width / experts / d_rnn.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Sequence

import jax

from repro.core import tfamily, vggops
from repro.configs.vgg_family import VGGConfig, union_config


@dataclass(frozen=True)
class VGGFamily:
    def union(self, cfgs: Sequence[VGGConfig]) -> VGGConfig:
        return union_config(list(cfgs))

    def depth_only(self, cfgs: Sequence[VGGConfig]) -> bool:
        """True when the cohort differs ONLY in depth (layer counts): the
        unified-space engine is then exact (DESIGN.md §2). Width layers must
        agree wherever two clients both have them, and every non-stage
        config field (classifier, n_classes, in_channels, ...) must match."""
        for si in range(max(len(c.stages) for c in cfgs)):
            for li in range(max(len(c.stages[si]) for c in cfgs
                                if si < len(c.stages))):
                ws = {c.stages[si][li] for c in cfgs
                      if si < len(c.stages) and li < len(c.stages[si])}
                if len(ws) > 1:
                    return False
        norm = {dataclasses.replace(c, name="", stages=()) for c in cfgs}
        return len(norm) == 1

    def segment_representable(self, cfgs: Sequence[VGGConfig]) -> bool:
        """True when every client's embedding into the cohort union is a
        segment operator (``core.segments``) — the unified engine's
        eligibility domain, superseding the old ``depth_only`` gate.
        Depth and width may both vary; non-structural fields must match,
        stage/classifier arity must match, and trailing union positions
        a client doesn't own must carry the client's stage-final width
        (the regime ``down()``'s within-stage walk is defined on)."""
        cfgs = list(cfgs)
        norm = {dataclasses.replace(c, name="", stages=(), classifier=())
                for c in cfgs}
        if len(norm) != 1:
            return False
        if (len({len(c.stages) for c in cfgs}) != 1
                or len({len(c.classifier) for c in cfgs}) != 1):
            return False
        union = union_config(cfgs)
        for c in cfgs:
            for si, ws in enumerate(c.stages):
                uw = union.stages[si]
                if any(uw[li] != ws[-1] for li in range(len(ws), len(uw))):
                    return False
        return True

    def segment_spec(self, client_cfg: VGGConfig, global_cfg: VGGConfig, *,
                     seed: int = 0):
        return vggops.segment_spec(client_cfg, global_cfg, seed=seed)

    def chain_paths(self, cfg: VGGConfig):
        """Sequential chain as (layer-id, params-tree path) pairs — the
        engine's FlexiFed grouping uses the ids to find the shared prefix
        and the paths to locate each layer in the (stacked) union tree."""
        out = []
        for si, ws in enumerate(cfg.stages):
            for li, w in enumerate(ws):
                out.append((("conv", si, li, w), ("stages", f"s{si}", f"c{li}")))
        for fi, wd in enumerate(cfg.classifier):
            out.append((("fc", fi, wd), ("fc", f"f{fi}")))
        out.append((("out",), ("out",)))
        return out

    def init(self, key, cfg):
        from repro.models import vgg
        return vgg.init_params(key, cfg)

    def up(self, params, from_cfg, to_cfg, *, seed=0):
        return vggops.up(params, from_cfg, to_cfg, seed=seed)

    def down(self, params, from_cfg, to_cfg, *, seed=0, mode="paper"):
        return vggops.down(params, from_cfg, to_cfg, seed=seed, mode=mode)

    def loss_and_grad(self, cfg):
        from repro.models import vgg

        def f(params, batch):
            return jax.value_and_grad(vgg.loss_fn, has_aux=True)(params, cfg, batch)
        return f

    def evaluate(self, params, cfg, batch):
        from repro.models import vgg
        logits = vgg.apply(params, cfg, batch["x"])
        return float((logits.argmax(-1) == batch["y"]).mean())


@dataclass(frozen=True)
class TransformerFamily:
    def union(self, cfgs):
        return tfamily.union(list(cfgs))

    def depth_only(self, cfgs) -> bool:
        """True when variants differ only in n_layers (zero-block padding is
        exact under pre-norm residuals); any other config difference makes
        the unified embedding approximate or invalid (DESIGN.md
        §Arch-applicability). Configs are frozen dataclasses, so normalize
        the depth-and-label fields away and compare whole."""
        norm = {dataclasses.replace(c, name="", n_layers=0) for c in cfgs}
        return len(norm) == 1

    def segment_representable(self, cfgs) -> bool:
        """Depth (n_layers) and FFN width (d_ff) may vary — both embed
        as segment operators (zero blocks / deterministic duplication).
        Expert count is affine (router-bias shift), d_rnn and d_model
        stay out of scope (DESIGN.md §Arch-applicability), so any other
        config difference keeps the loop."""
        norm = {dataclasses.replace(c, name="", n_layers=0, d_ff=0)
                for c in cfgs}
        return len(norm) == 1

    def segment_spec(self, client_cfg, global_cfg, *, seed: int = 0):
        return tfamily.segment_spec(client_cfg, global_cfg, seed=seed)

    def chain_paths(self, cfg):
        raise NotImplementedError(
            "FlexiFed's sequential-prefix grouping is defined for the VGG "
            "chain only (paper Section IV.A.3)")

    def init(self, key, cfg):
        from repro.models import transformer as T
        return T.init_params(key, cfg)

    def up(self, params, from_cfg, to_cfg, *, seed=0):
        return tfamily.up(params, from_cfg, to_cfg, seed=seed)

    def down(self, params, from_cfg, to_cfg, *, seed=0, mode="paper"):
        return tfamily.down(params, from_cfg, to_cfg, seed=seed, mode=mode)

    def loss_and_grad(self, cfg, *, ctx=None):
        from repro.launch.steps import lm_loss
        from repro.sharding.ctx import CPU_CTX
        ctx = CPU_CTX if ctx is None else ctx

        def f(params, batch):
            (loss, aux), g = jax.value_and_grad(lm_loss, has_aux=True)(
                params, cfg, batch, ctx=ctx)
            return (loss, aux), g
        return f

    def evaluate(self, params, cfg, batch):
        return float(_lm_eval_loss(params, cfg, batch))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _lm_eval_loss(params, cfg, batch):
    """Jitted eval loss: an eager ``lm_loss`` call re-traces the unit
    scan (and pays an XLA compile) on EVERY evaluation; keying one jit
    on the static config makes round >= 2 evals compile-free."""
    from repro.launch.steps import lm_loss
    return lm_loss(params, cfg, batch)[0]
