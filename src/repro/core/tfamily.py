"""NetChange generalized to the transformer families of the assigned pool
(beyond-paper: the paper only treats VGG; see DESIGN.md §2).

Client variants of a family vary in
  * depth        — number of pattern units (stacked leading axis),
  * FFN width    — d_ff / d_ff_expert / shared width,
  * expert count — MoE routed experts,
  * d_rnn        — RG-LRU recurrent width.
d_model / heads / vocab are held fixed within a family: widening d_model
through an RMSNorm is NOT function preserving (the rms denominator changes
under channel duplication) — recorded in DESIGN.md §Arch-applicability.

Transforms:
  up():   To-Wider (Net2Net duplicate+split, exact) + To-Deeper (all-zero
          blocks => identity under pre-norm residual, exact).
  down(): To-Narrower (paper Alg. 3 mass-redistribution, lossy; or the
          beyond-paper ``fold`` inverse) + To-Shallower (slice the stack).

MoE expert duplication copies expert weights and shifts duplicated router
columns by -log(group size): exact under soft routing, approximate under
top-k (noted).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import netchange as nc
from repro.core import segments as sg
from repro.models import transformer as T


# ----------------------------------------------------------------- variants

def make_variant(cfg: ModelConfig, *, n_units: Optional[int] = None,
                 ffn_scale: float = 1.0, n_experts: Optional[int] = None,
                 d_rnn: Optional[int] = None) -> ModelConfig:
    kw: Dict[str, Any] = {}
    if n_units is not None:
        assert 1 <= n_units <= cfg.n_units
        kw["n_layers"] = n_units * cfg.pattern_len + len(cfg.rem_kinds)
    if ffn_scale != 1.0 and cfg.d_ff:
        kw["d_ff"] = _round8(cfg.d_ff * ffn_scale)
    if cfg.moe is not None:
        m = cfg.moe
        # ffn_scale=1.0 must be the identity: rounding an unscaled width
        # through _round8 would silently mutate the config (and push the
        # cohort out of the segment-representable domain)
        kw["moe"] = dataclasses.replace(
            m,
            n_experts=n_experts if n_experts is not None else m.n_experts,
            top_k=min(m.top_k, n_experts if n_experts is not None else m.n_experts),
            d_ff_expert=(_round8(m.d_ff_expert * ffn_scale)
                         if ffn_scale != 1.0 else m.d_ff_expert),
            d_ff_shared=(_round8(m.d_ff_shared * ffn_scale)
                         if ffn_scale != 1.0 and m.n_shared else m.d_ff_shared),
        )
    if d_rnn is not None and cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_rnn=d_rnn)
    name = cfg.name + f"-u{n_units or cfg.n_units}f{ffn_scale}e{n_experts or 0}"
    return dataclasses.replace(cfg, name=name, **kw)


def _round8(x: float) -> int:
    return max(8, int(round(x / 8) * 8))


def union(cfgs) -> ModelConfig:
    """Global architecture = elementwise max (paper §III.B)."""
    base = max(cfgs, key=lambda c: c.n_layers)
    kw: Dict[str, Any] = {
        "n_layers": max(c.n_layers for c in cfgs),
        "d_ff": max(c.d_ff for c in cfgs),
        "name": cfgs[0].name.split("-u")[0] + "-union",
    }
    if base.moe is not None:
        kw["moe"] = dataclasses.replace(
            base.moe,
            n_experts=max(c.moe.n_experts for c in cfgs),
            top_k=max(c.moe.top_k for c in cfgs),
            d_ff_expert=max(c.moe.d_ff_expert for c in cfgs),
            d_ff_shared=max(c.moe.d_ff_shared for c in cfgs),
        )
    if base.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            base.ssm, d_rnn=max(c.d_rnn for c in cfgs))
    return dataclasses.replace(base, **kw)


# ----------------------------------------------------- per-block transforms

def _ffn_leaves(block: Dict) -> Dict[str, Any]:
    """Return {key: (container, in/out role, axis-from-end)} for FFN width."""
    roles = {}
    if "mlp" in block:
        roles["mlp"] = block["mlp"]
    if "moe" in block and "shared" in block["moe"]:
        roles["shared"] = block["moe"]["shared"]
    return roles


_MLP_SPEC = {"wg": ("in", -1), "wu": ("in", -1), "wi": ("in", -1),
             "bi": ("in", -1), "wd": ("out", -2)}
# bd (output bias) is width-invariant.


def _apply_width(w, role, axis, mapping, old, mode):
    if mode == "widen":
        return (nc.widen_in(w, mapping, axis=axis) if role == "in"
                else nc.widen_out(w, mapping, old, axis=axis))
    if mode == "narrow_paper":
        n_tar = len(mapping)  # here mapping is unused; n_tar passed via old
        raise RuntimeError("use _apply_narrow_paper")
    # narrow_fold
    return (nc.narrow_fold_in(w, mapping, old, axis=axis) if role == "in"
            else nc.narrow_fold_out(w, mapping, old, axis=axis))


def _transform_mlp(mlp, old: int, new: int, tag: str, seed: int, mode: str):
    out = dict(mlp)
    if mode == "widen":
        mapping = nc.dup_mapping(old, new, tag=tag, seed=seed)
        for k, (role, ax) in _MLP_SPEC.items():
            if k in out:
                out[k] = _apply_width(out[k], role, ax, mapping, old, mode)
    elif mode == "narrow_paper":
        for k, (role, ax) in _MLP_SPEC.items():
            if k not in out:
                continue
            out[k] = (nc.narrow_in(out[k], new, axis=ax) if role == "in"
                      else nc.narrow_out_paper(out[k], new, axis=ax))
    else:  # narrow_fold: mapping new(client)->... built as dup(new, old)
        mapping = nc.dup_mapping(new, old, tag=tag, seed=seed)
        for k, (role, ax) in _MLP_SPEC.items():
            if k in out:
                out[k] = _apply_width(out[k], role, ax, mapping, new, mode)
    return out


_EXPERT_AXIS = {"wg": -3, "wu": -3, "wd": -3}


def _transform_experts(moe, old_e: int, new_e: int, tag: str, seed: int,
                       mode: str):
    """Expert-count change: duplicate whole experts; router columns get a
    -log(group size) shift (exact under soft routing)."""
    out = dict(moe)
    if mode == "widen":
        mapping = nc.dup_mapping(old_e, new_e, tag=tag + "/exp", seed=seed)
        counts = nc.mapping_counts(mapping, old_e)
        for k, ax in _EXPERT_AXIS.items():
            out[k] = nc.widen_in(out[k], mapping, axis=ax)
        out["router"] = nc.widen_in(out["router"], mapping, axis=-1)
        # logit shift lives in the router BIAS: softmax mass of a duplicate
        # group equals the original expert's mass (exact under soft routing)
        b = nc.widen_in(out["router_b"], mapping, axis=-1)
        shift = jnp.asarray(np.log(counts[mapping]).astype(np.float32))
        out["router_b"] = b - shift.astype(b.dtype)
    elif mode == "narrow_paper":
        for k, ax in _EXPERT_AXIS.items():
            out[k] = nc.narrow_in(out[k], new_e, axis=ax)
        out["router"] = nc.narrow_in(out["router"], new_e, axis=-1)
        out["router_b"] = nc.narrow_in(out["router_b"], new_e, axis=-1)
    else:
        mapping = nc.dup_mapping(new_e, old_e, tag=tag + "/exp", seed=seed)
        counts = nc.mapping_counts(mapping, new_e)
        for k, ax in _EXPERT_AXIS.items():
            out[k] = nc.narrow_fold_in(out[k], mapping, new_e, axis=ax)
        out["router"] = nc.narrow_fold_in(out["router"], mapping, new_e,
                                          axis=-1)
        b = nc.narrow_fold_in(out["router_b"], mapping, new_e, axis=-1)
        shift = jnp.asarray(np.log(counts).astype(np.float32))
        out["router_b"] = b + shift.astype(b.dtype)
    return out


_RG_SPEC = {"win": ("in", -1), "wgate": ("in", -1), "conv": ("in", -1),
            "ba": ("in", -1), "bx": ("in", -1), "lam": ("in", -1),
            "wa": ("both", None), "wx": ("both", None),
            "wout": ("out", -2)}


def _transform_rg(rg, old: int, new: int, tag: str, seed: int, mode: str):
    out = dict(rg)
    if mode == "narrow_paper":
        for k, (role, ax) in _RG_SPEC.items():
            if role == "in":
                out[k] = nc.narrow_in(out[k], new, axis=ax)
            elif role == "out":
                out[k] = nc.narrow_out_paper(out[k], new, axis=ax)
            else:  # both: rows redistribute, cols drop
                out[k] = nc.narrow_in(nc.narrow_out_paper(out[k], new, axis=-2),
                                      new, axis=-1)
        return out
    if mode == "widen":
        mapping = nc.dup_mapping(old, new, tag=tag + "/rnn", seed=seed)
        base = old
        fn_in = lambda w, ax: nc.widen_in(w, mapping, axis=ax)
        fn_out = lambda w, ax: nc.widen_out(w, mapping, base, axis=ax)
    else:
        mapping = nc.dup_mapping(new, old, tag=tag + "/rnn", seed=seed)
        base = new
        fn_in = lambda w, ax: nc.narrow_fold_in(w, mapping, base, axis=ax)
        fn_out = lambda w, ax: nc.narrow_fold_out(w, mapping, base, axis=ax)
    for k, (role, ax) in _RG_SPEC.items():
        if role == "in":
            out[k] = fn_in(out[k], ax)
        elif role == "out":
            out[k] = fn_out(out[k], ax)
        else:
            out[k] = fn_in(fn_out(out[k], -2), -1)
    return out


def _transform_block(block, from_cfg: ModelConfig, to_cfg: ModelConfig,
                     tag: str, seed: int, mode: str):
    out = dict(block)
    if "mlp" in out and from_cfg.d_ff != to_cfg.d_ff:
        out["mlp"] = _transform_mlp(out["mlp"], from_cfg.d_ff, to_cfg.d_ff,
                                    tag + "/ffn", seed, mode)
    if "moe" in out:
        mf, mt = from_cfg.moe, to_cfg.moe
        moe = dict(out["moe"])
        if mf.d_ff_expert != mt.d_ff_expert:
            sub = {k: moe[k] for k in ("wg", "wu", "wd")}
            sub = _transform_mlp(sub, mf.d_ff_expert, mt.d_ff_expert,
                                 tag + "/effn", seed, mode)
            moe.update(sub)
        if "shared" in moe and mf.d_ff_shared != mt.d_ff_shared:
            moe["shared"] = _transform_mlp(
                moe["shared"], mf.n_shared * mf.d_ff_shared,
                mt.n_shared * mt.d_ff_shared, tag + "/sffn", seed, mode)
        if mf.n_experts != mt.n_experts:
            moe = _transform_experts(moe, mf.n_experts, mt.n_experts,
                                     tag, seed, mode)
        out["moe"] = moe
    if "rg" in out and from_cfg.d_rnn != to_cfg.d_rnn:
        out["rg"] = _transform_rg(out["rg"], from_cfg.d_rnn, to_cfg.d_rnn,
                                  tag, seed, mode)
    return out


@functools.lru_cache(maxsize=32)
def _param_shapes(cfg: ModelConfig):
    # configs are frozen/hashable and per-round seed-keyed callers would
    # otherwise re-trace the full model every round
    return jax.eval_shape(lambda k: T.init_params(k, cfg),
                          jax.random.PRNGKey(0))


def segment_spec(from_cfg: ModelConfig, to_cfg: ModelConfig, *,
                 seed: int = 0):
    """Width-segment metadata of ``up(·, from_cfg, to_cfg, seed=seed)``
    (``core.segments``) for every LINEAR width dimension
    ``_transform_block`` moves: FFN d_ff, MoE expert width d_ff_expert,
    shared-expert width, RG-LRU d_rnn. Per widened leaf: in-role
    duplication on the hidden axis (−1), out-role split on the
    down-projection rows (−2), both on the recurrent square matrices —
    with each block's own deterministic mapping (same tags
    ``_transform_block`` uses, so the ids match ``up`` exactly).

    Expert-COUNT duplication is NOT emitted: its router-bias −log(group)
    shift makes the embedding affine per expert group, so cohorts
    differing there carry no segment metadata (multiplicity stays 1 on
    expert-duplicated coordinates; the unified engine's
    ``segment_representable`` excludes them anyway)."""
    spec = {}
    mf, mt = from_cfg.moe, to_cfg.moe
    ffn = (from_cfg.d_ff, to_cfg.d_ff)
    effn = (mf.d_ff_expert, mt.d_ff_expert) if mf and mt else (0, 0)
    sffn = ((mf.n_shared * mf.d_ff_shared, mt.n_shared * mt.d_ff_shared)
            if mf and mt else (0, 0))
    rnn = ((from_cfg.d_rnn, to_cfg.d_rnn)
           if from_cfg.ssm and to_cfg.ssm else (0, 0))
    if all(a == b for a, b in (ffn, effn, sffn, rnn)):
        return spec
    shapes = _param_shapes(to_cfg)

    def segs(role, ax, mapping):
        if role == "both":
            return [sg.AxisSeg(-2, mapping, out_role=True),
                    sg.AxisSeg(-1, mapping, out_role=False)]
        return [sg.AxisSeg(ax, mapping, out_role=(role == "out"))]

    def visit(path, leaf):
        keys = sg.path_keys(path)
        if (keys[:2] == ("encoder", "units") and len(keys) == 4
                and keys[2] == "mlp" and keys[3] in _MLP_SPEC):
            # whisper encoder FFN rides cfg.d_ff too — one mapping shared
            # by all (stacked) encoder layers, same tag ``up()`` uses
            old, new = ffn
            if old != new:
                role, ax = _MLP_SPEC[keys[3]]
                spec[keys] = segs(role, ax,
                                  nc.dup_mapping(old, new, tag="e/ffn",
                                                 seed=seed))
            return leaf
        if len(keys) < 3 or keys[0] not in ("units", "rem"):
            return leaf
        tag0 = ("u" if keys[0] == "units" else "r") + f"/{keys[1]}"
        rest = keys[2:]
        hit = None
        if rest[0] == "mlp" and len(rest) == 2 and rest[1] in _MLP_SPEC:
            hit = (ffn, tag0 + "/ffn", _MLP_SPEC[rest[1]])
        elif (rest[0] == "moe" and len(rest) == 2
                and rest[1] in ("wg", "wu", "wd")):
            hit = (effn, tag0 + "/effn", _MLP_SPEC[rest[1]])
        elif (len(rest) == 3 and rest[:2] == ("moe", "shared")
                and rest[2] in _MLP_SPEC):
            hit = (sffn, tag0 + "/sffn", _MLP_SPEC[rest[2]])
        elif rest[0] == "rg" and len(rest) == 2 and rest[1] in _RG_SPEC:
            hit = (rnn, tag0 + "/rnn", _RG_SPEC[rest[1]])
        if hit is None:
            return leaf
        (old, new), tag, (role, ax) = hit
        if old != new:
            spec[keys] = segs(role, ax,
                              nc.dup_mapping(old, new, tag=tag, seed=seed))
        return leaf

    jax.tree_util.tree_map_with_path(visit, shapes)
    return spec


# ------------------------------------------------------------------ up/down

def _transform_encoder(params, from_cfg: ModelConfig, to_cfg: ModelConfig,
                       seed: int, mode: str):
    """The whisper encoder's FFN is sized by ``cfg.d_ff`` like the
    decoder blocks, so width transforms must move it too (found by the
    ``repro.analysis`` contract checker: ``up`` used to pass the
    ``encoder`` subtree through untouched, leaving d_ff-heterogeneous
    encoder cohorts shape-broken). Encoder DEPTH lives in
    ``cfg.encoder.n_layers`` and never varies inside a family, so only
    the MLP width moves — one shared mapping (tag ``e/ffn``) across the
    stacked encoder layers, matching ``segment_spec``."""
    if "encoder" not in params or from_cfg.d_ff == to_cfg.d_ff:
        return params
    enc = dict(params["encoder"])
    units = dict(enc["units"])
    units["mlp"] = _transform_mlp(units["mlp"], from_cfg.d_ff, to_cfg.d_ff,
                                  "e/ffn", seed, mode)
    enc["units"] = units
    params["encoder"] = enc
    return params


def _zeros_block_like(cfg: ModelConfig, kind: str):
    shapes = jax.eval_shape(
        lambda: T.block_init(jax.random.PRNGKey(0), cfg, kind,
                             jnp.dtype(cfg.dtype)))
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def up(params, from_cfg: ModelConfig, to_cfg: ModelConfig, *, seed: int = 0):
    """Client -> global: To-Wider (exact) + To-Deeper (zero blocks, exact)."""
    assert from_cfg.layer_pattern == to_cfg.layer_pattern
    params = jax.tree.map(lambda x: x, params)
    # widths first (existing blocks), at client depth
    if "units" in params:
        params["units"] = {
            k: _transform_block(v, from_cfg, to_cfg, f"u/{k}", seed, "widen")
            for k, v in params["units"].items()}
    if "rem" in params:
        params["rem"] = {
            k: _transform_block(v, from_cfg, to_cfg, f"r/{k}", seed, "widen")
            for k, v in params["rem"].items()}
    params = _transform_encoder(params, from_cfg, to_cfg, seed, "widen")
    # depth: pad the stacked axis with zero blocks (identity via residual)
    nu_from, nu_to = from_cfg.n_units, to_cfg.n_units
    if nu_to > nu_from:
        for i, kind in enumerate(to_cfg.layer_pattern):
            zb = _zeros_block_like(to_cfg, kind)
            pad = jax.tree.map(
                lambda z: jnp.broadcast_to(z[None], (nu_to - nu_from,) + z.shape),
                zb)
            params["units"][f"b{i}"] = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                params["units"][f"b{i}"], pad)
    return params


def down(params, from_cfg: ModelConfig, to_cfg: ModelConfig, *, seed: int = 0,
         mode: str = "paper"):
    """Global -> client: To-Shallower (slice) + To-Narrower (Alg.3 | fold)."""
    assert from_cfg.layer_pattern == to_cfg.layer_pattern
    nmode = "narrow_paper" if mode == "paper" else "narrow_fold"
    params = jax.tree.map(lambda x: x, params)
    nu_to = to_cfg.n_units
    if nu_to < from_cfg.n_units:
        params["units"] = jax.tree.map(lambda x: x[:nu_to], params["units"])
    if "units" in params:
        params["units"] = {
            k: _transform_block(v, from_cfg, to_cfg, f"u/{k}", seed, nmode)
            for k, v in params["units"].items()}
    if "rem" in params:
        params["rem"] = {
            k: _transform_block(v, from_cfg, to_cfg, f"r/{k}", seed, nmode)
            for k, v in params["rem"].items()}
    return _transform_encoder(params, from_cfg, to_cfg, seed, nmode)
