"""Quantized wire formats for plane aggregation (DESIGN.md §10).

What a client ships each round is a packed ``(P,)`` plane row (or a
``(K_chunk, P)`` chunk of rows — ``core.plane``); this module defines
how those rows encode on the wire:

  * ``"f32"``   — the uncompressed baseline: full f32 rows, no encoding.
  * ``"bf16"``  — a plain dtype cast, 2 bytes/coordinate, no side data.
                  The aggregation kernels cast every operand to f32
                  internally, so bf16 chunks stream through the SAME
                  fused accumulate pass as f32 ones.
  * ``"int8"``  — symmetric per-tile quantization, 1 byte/coordinate
                  plus one f32 scale per ``tile`` coordinates: the row
                  splits into dense tiles of ``tile`` columns (a lane
                  multiple, default 256), each tile carries
                  ``scale = max|x| / 127`` and ``q = round(x / scale)``
                  clipped to [-127, 127].  Dequantization is ``q·scale``
                  — fused into the streaming accumulate by
                  ``kernels/fedavg.plane_accum_q`` so the cohort is
                  never materialized in f32.

Sparsity rides the coverage mask: a narrow client covers only a subset
of the union plane's coordinates (``core.segments`` /
``aggregation.coverage_mask`` describe which), and under
``agg_mode="coverage"`` the uncovered coordinates never enter the
average — so the client need not ship them at all.  ``encode`` with a
0/1 ``mask`` zeroes the off-mask coordinates before quantizing (a zero
int8 payload compresses to nothing on the wire; ``payload_nbytes``
counts only the covered coordinates), and the masked accumulate kernel
reproduces the dense result exactly.

Error feedback (Seide et al.; Karimireddy et al., 2019) keeps the
quantization unbiased ACROSS rounds: each client holds a residual ``e``
(f32, client-side only — never on the wire) and encodes
``q = Q(x + e)``, ``e' = (x + e) - deq(q)``, so the noise a round drops
is re-injected the next round instead of accumulating.  The residual
identity ``deq(q) + e' == x + e`` is checked by the contract verifier
(``analysis/contracts.py``); residual planes persist through
``checkpoint.save_plane`` so resumed compressed runs bit-match
uninterrupted ones (``fl/federation.py``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

WIRE_FORMATS = ("f32", "bf16", "int8")
INT8_MAX = 127.0
DEFAULT_TILE = 256   # scale granularity: one f32 scale per `tile` coords
_LANE = 128          # tiles must be lane multiples (kernels/fedavg.LANE)

_ITEMSIZE = {"f32": 4, "bf16": 2, "int8": 1}


def wire_itemsize(fmt: str) -> int:
    """Bytes per coordinate of the VALUES payload."""
    if fmt not in WIRE_FORMATS:
        raise ValueError(f"wire={fmt!r}, expected one of {WIRE_FORMATS}")
    return _ITEMSIZE[fmt]


def validate_tile(tile: int) -> int:
    if (isinstance(tile, bool) or not isinstance(tile, int)
            or tile < _LANE or tile % _LANE):
        raise ValueError(f"wire tile={tile!r} must be a positive multiple "
                         f"of {_LANE} (lane-aligned scale tiles)")
    return tile


def n_tiles(n: int, tile: int = DEFAULT_TILE) -> int:
    """Number of scale tiles covering an ``n``-coordinate row (the last
    tile may straddle the row end; its scale is computed over the real
    coordinates only)."""
    return -(-int(n) // int(tile))


def _tiled(x: jnp.ndarray, tile: int) -> jnp.ndarray:
    """(..., n) -> (..., n_tiles, tile), zero-padded to a tile multiple."""
    n = x.shape[-1]
    pad = (-n) % tile
    if pad:
        width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, width)
    return x.reshape(x.shape[:-1] + (-1, tile))


def quantize(x, fmt: str, *, tile: int = DEFAULT_TILE, mask=None
             ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Encode ``x`` (..., n) f32 for the wire -> ``(values, scales)``.

    ``fmt="f32"``/``"bf16"``: a cast, ``scales`` is None.  ``"int8"``:
    symmetric per-tile quantization — ``scales`` has shape
    ``(..., n_tiles(n, tile))``, all-zero tiles get scale 0 (their
    payload is exactly 0, and dequantization multiplies by the raw
    scale, so 0·0 round-trips).  A 0/1 ``mask`` zeroes off-mask
    coordinates BEFORE the scale is computed (the sparse wire: only
    covered coordinates ship; scales adapt to the covered values).
    """
    x = jnp.asarray(x, jnp.float32)
    if mask is not None:
        x = x * jnp.asarray(mask, jnp.float32)
    if fmt == "f32":
        return x, None
    if fmt == "bf16":
        return x.astype(jnp.bfloat16), None
    if fmt != "int8":
        raise ValueError(f"wire={fmt!r}, expected one of {WIRE_FORMATS}")
    tile = validate_tile(tile)
    n = x.shape[-1]
    xt = _tiled(x, tile)
    scales = jnp.max(jnp.abs(xt), axis=-1) / INT8_MAX
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(xt / safe[..., None]), -INT8_MAX, INT8_MAX)
    q = q.astype(jnp.int8).reshape(x.shape[:-1] + (-1,))[..., :n]
    return q, scales


def dequantize(values, scales=None, *, tile: int = DEFAULT_TILE
               ) -> jnp.ndarray:
    """Decode a wire payload back to f32.  int8 payloads need their
    ``scales``; bf16/f32 are casts (``scales`` ignored/None)."""
    values = jnp.asarray(values)
    if values.dtype != jnp.int8:
        return values.astype(jnp.float32)
    assert scales is not None, "int8 payloads need their per-tile scales"
    tile = validate_tile(tile)
    n = values.shape[-1]
    qt = _tiled(values.astype(jnp.float32), tile)
    x = qt * jnp.asarray(scales, jnp.float32)[..., None]
    return x.reshape(values.shape[:-1] + (-1,))[..., :n]


def encode(x, residual, fmt: str, *, tile: int = DEFAULT_TILE, mask=None
           ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], jnp.ndarray]:
    """Error-feedback encode: ``q = Q(x + e)`` ->
    ``(values, scales, new_residual)``.

    The residual identity ``deq(values, scales) + new_residual == x + e``
    holds on every shipped (on-``mask``) coordinate; off-mask
    coordinates carry no payload AND no residual (they never enter the
    coverage average, so there is no noise to feed back).
    ``residual=None`` starts from zero (round 0).
    """
    x = jnp.asarray(x, jnp.float32)
    e = jnp.zeros_like(x) if residual is None else \
        jnp.asarray(residual, jnp.float32)
    xe = x + e
    values, scales = quantize(xe, fmt, tile=tile, mask=mask)
    new_e = xe - dequantize(values, scales, tile=tile)
    if mask is not None:
        new_e = new_e * jnp.asarray(mask, jnp.float32)
    return values, scales, new_e


def values_nbytes(fmt: str, count: int) -> int:
    """Bytes of the VALUES payload for ``count`` shipped coordinates."""
    return int(count) * wire_itemsize(fmt)


def scales_nbytes(fmt: str, n: int, *, tile: int = DEFAULT_TILE) -> int:
    """Bytes of the scale side-channel (int8 only: one f32 per tile,
    dense over the row — sparsity does not thin the scale grid)."""
    return 4 * n_tiles(n, tile) if fmt == "int8" else 0


def payload_nbytes(fmt: str, n: int, *, tile: int = DEFAULT_TILE,
                   covered: Optional[int] = None) -> int:
    """Total wire bytes for one ``n``-coordinate row: values (all ``n``
    coordinates dense, or only ``covered`` of them under the sparse
    wire) + the dense per-tile scales for int8."""
    count = n if covered is None else covered
    return values_nbytes(fmt, count) + scales_nbytes(fmt, n, tile=tile)
