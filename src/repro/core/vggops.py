"""NetChange wired to the VGG family — the paper's own setting.

A VGG variant is a sequential chain  conv* (pool) ... conv* (pool) fc* out.
``up()`` transforms client params to the global architecture (To-Deeper +
To-Wider, Alg. 2); ``down()`` transforms global params to a client
architecture (To-Shallower + To-Narrower, Alg. 3 — or the beyond-paper
``fold`` inverse).

Depth alignment is front-aligned per stage: To-Deeper appends identity
convs at the END of a stage (exact identity under ReLU), To-Shallower
drops them from the end. Width ops adjust the *next* layer in the chain;
the conv->fc flatten boundary is handled by grouping fc rows by channel.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg_family import VGGConfig
from repro.core import netchange as nc
from repro.core import segments as sg


def _chain(cfg: VGGConfig) -> List[Tuple]:
    out = []
    for si, ws in enumerate(cfg.stages):
        for li in range(len(ws)):
            out.append(("conv", si, li))
    for fi in range(len(cfg.classifier)):
        out.append(("fc", fi))
    out.append(("out",))
    return out


def _get(params, node):
    if node[0] == "conv":
        return params["stages"][f"s{node[1]}"][f"c{node[2]}"]
    if node[0] == "fc":
        return params["fc"][f"f{node[1]}"]
    return params["out"]


def _set(params, node, value):
    if node[0] == "conv":
        params["stages"][f"s{node[1]}"][f"c{node[2]}"] = value
    elif node[0] == "fc":
        params["fc"][f"f{node[1]}"] = value
    else:
        params["out"] = value


def _width_of(cfg: VGGConfig, node) -> int:
    if node[0] == "conv":
        return cfg.stages[node[1]][node[2]]
    if node[0] == "fc":
        return cfg.classifier[node[1]]
    return cfg.n_classes


def _spatial_after_convs(cfg: VGGConfig) -> int:
    return cfg.image_size // (2 ** len(cfg.stages))


def _widen_next_in(nxt, nxt_node, mapping, old, cfg, *, fold=False,
                   flatten=False):
    """Duplicate (or fold) the incoming channels of the next layer.
    ``flatten`` marks the conv→fc boundary (the widened node is a conv
    and the next is the first fc): rows are (spatial, channel) pairs,
    channel fastest. fc→fc/out adjustments are plain row ops."""
    w = nxt["w"]
    if nxt_node[0] == "conv":
        nxt["w"] = (nc.narrow_fold_out(w, mapping, old, axis=2) if fold
                    else nc.widen_out(w, mapping, old, axis=2))
        return nxt
    if not flatten:
        nxt["w"] = (nc.narrow_fold_out(w, mapping, old, axis=0) if fold
                    else nc.widen_out(w, mapping, old, axis=0))
        return nxt
    sp = _spatial_after_convs(cfg) ** 2
    w3 = w.reshape(sp, -1, w.shape[1])
    w3 = (nc.narrow_fold_out(w3, mapping, old, axis=1) if fold
          else nc.widen_out(w3, mapping, old, axis=1))
    nxt["w"] = w3.reshape(-1, w.shape[1])
    return nxt


def _narrow_next_in_paper(nxt, nxt_node, n_tar, cfg, *, flatten=False):
    w = nxt["w"]
    if nxt_node[0] == "conv":
        nxt["w"] = nc.narrow_out_paper(w, n_tar, axis=2)
        return nxt
    if not flatten:
        nxt["w"] = nc.narrow_out_paper(w, n_tar, axis=0)
        return nxt
    sp = _spatial_after_convs(cfg) ** 2
    w3 = w.reshape(sp, -1, w.shape[1])
    nxt["w"] = nc.narrow_out_paper(w3, n_tar, axis=1).reshape(-1, w.shape[1])
    return nxt


def _copy(params):
    return jax.tree.map(lambda x: x, params)


def _mid_widths(from_cfg: VGGConfig, to_cfg: VGGConfig) -> Dict[Tuple, int]:
    """Chain-node -> width AFTER To-Deeper but BEFORE To-Wider (inserted
    identity convs carry their stage's last client width) — the "old"
    side of every To-Wider mapping. The ONE definition ``up()`` and
    ``segment_spec`` share, so the spec cannot drift from the embedding
    it describes."""
    mid = tuple(
        tuple(list(from_cfg.stages[si]) + [from_cfg.stages[si][-1]]
              * (len(to_cfg.stages[si]) - len(from_cfg.stages[si])))
        for si in range(len(to_cfg.stages)))
    return {**{("conv", si, li): mid[si][li]
               for si in range(len(mid)) for li in range(len(mid[si]))},
            **{("fc", fi): from_cfg.classifier[fi]
               for fi in range(len(from_cfg.classifier))}}


def up(params, from_cfg: VGGConfig, to_cfg: VGGConfig, *, seed: int = 0):
    """Client -> global: To-Deeper then To-Wider (both function preserving)."""
    params = _copy(params)
    # --- To-Deeper: append identity convs at the end of each stage
    for si, ws_to in enumerate(to_cfg.stages):
        ws_from = from_cfg.stages[si]
        assert len(ws_to) >= len(ws_from), (si, ws_from, ws_to)
        ch = ws_from[-1]
        stage = params["stages"][f"s{si}"]
        for li in range(len(ws_from), len(ws_to)):
            stage[f"c{li}"] = {
                "w": nc.identity_conv(ch, dtype=stage["c0"]["w"].dtype),
                "b": jnp.zeros((ch,), stage["c0"]["b"].dtype)}
    # --- To-Wider over the whole chain (Alg. 2)
    chain = _chain(to_cfg)
    cur_widths = _mid_widths(from_cfg, to_cfg)
    for idx, node in enumerate(chain[:-1]):
        old = cur_widths[node if node[0] != "conv" else ("conv", node[1], node[2])]
        new = _width_of(to_cfg, node)
        if new == old:
            continue
        tag = "/".join(map(str, node))
        mapping = nc.dup_mapping(old, new, tag=tag, seed=seed)
        layer = dict(_get(params, node))
        out_axis = 3 if node[0] == "conv" else 1
        layer["w"] = nc.widen_in(layer["w"], mapping, axis=out_axis)
        layer["b"] = nc.widen_in(layer["b"], mapping, axis=0)
        _set(params, node, layer)
        nxt_node = chain[idx + 1]
        nxt = dict(_get(params, nxt_node))
        nxt = _widen_next_in(nxt, nxt_node, mapping, old, to_cfg, fold=False,
                             flatten=(node[0] == "conv"))
        _set(params, nxt_node, nxt)
    return params


def segment_spec(from_cfg: VGGConfig, to_cfg: VGGConfig, *, seed: int = 0):
    """Width-segment metadata of ``up(·, from_cfg, to_cfg, seed=seed)``:
    per client-owned union leaf, which axes To-Wider duplicated and the
    segment id of every union index along them (``core.segments``).

    Mirrors ``up()``'s chain walk exactly: a node's own mapping widens
    its output axis (in-role duplication on w and b), and the *previous*
    chain node's mapping widens its input axis (out-role split on w) —
    including when the previous node is an inserted identity conv, whose
    widening still duplicates the next client layer's input channels.
    The conv→fc flatten boundary lifts the channel mapping to (spatial,
    channel) rows, channel fastest, matching ``_widen_next_in``."""
    spec = {}
    chain = _chain(to_cfg)
    cur_widths = _mid_widths(from_cfg, to_cfg)

    def is_client(node):
        if node[0] == "conv":
            return node[2] < len(from_cfg.stages[node[1]])
        return True

    def path_of(node):
        if node[0] == "conv":
            return ("stages", f"s{node[1]}", f"c{node[2]}")
        if node[0] == "fc":
            return ("fc", f"f{node[1]}")
        return ("out",)

    prev = prev_node = None          # previous chain node's (mapping, new)
    for node in chain:
        segs_w, segs_b = [], []
        if prev is not None and is_client(node):
            mapping_p, new_p = prev
            if node[0] == "conv":
                segs_w.append(sg.AxisSeg(2, mapping_p, out_role=True))
            elif prev_node[0] == "conv":
                # fc after flatten: rows are (spatial, channel), channel
                # fastest — lift the channel segments to row granularity
                sp = _spatial_after_convs(to_cfg) ** 2
                ids = (np.arange(sp)[:, None] * new_p
                       + np.asarray(mapping_p)[None, :]).reshape(-1)
                segs_w.append(sg.AxisSeg(0, ids.astype(np.int32),
                                         out_role=True))
            else:
                segs_w.append(sg.AxisSeg(0, mapping_p, out_role=True))
        own = None
        if node != ("out",):
            old = cur_widths[node]
            new = _width_of(to_cfg, node)
            if new != old:
                tag = "/".join(map(str, node))
                own = (nc.dup_mapping(old, new, tag=tag, seed=seed), new)
        if own is not None and is_client(node):
            out_axis = 3 if node[0] == "conv" else 1
            segs_w.append(sg.AxisSeg(out_axis, own[0], out_role=False))
            segs_b.append(sg.AxisSeg(0, own[0], out_role=False))
        if is_client(node):
            p = path_of(node)
            if segs_w:
                spec[p + ("w",)] = segs_w
            if segs_b:
                spec[p + ("b",)] = segs_b
        prev, prev_node = own, node
    return spec


def down(params, from_cfg: VGGConfig, to_cfg: VGGConfig, *, seed: int = 0,
         mode: str = "paper"):
    """Global -> client: To-Narrower (Alg. 3 or fold) then To-Shallower."""
    assert mode in ("paper", "fold")
    params = _copy(params)
    # --- To-Narrower over the chain (widths of layers the client keeps)
    chain = _chain(from_cfg)
    for idx, node in enumerate(chain[:-1]):
        if node[0] == "conv":
            si, li = node[1], node[2]
            if li >= len(to_cfg.stages[si]):
                continue                       # layer will be dropped
            new = to_cfg.stages[si][li]
        else:
            new = to_cfg.classifier[node[1]]
        old = _width_of(from_cfg, node)
        if new == old:
            continue
        assert new < old
        layer = dict(_get(params, node))
        out_axis = 3 if node[0] == "conv" else 1
        # find the next *kept* layer for the incoming adjustment: for VGG
        # this is simply the next layer in the chain because within-stage
        # trailing drops keep channel widths compatible.
        nxt_node = chain[idx + 1]
        nxt = dict(_get(params, nxt_node))
        if mode == "paper":
            layer["w"] = nc.narrow_in(layer["w"], new, axis=out_axis)
            layer["b"] = nc.narrow_in(layer["b"], new, axis=0)
            nxt = _narrow_next_in_paper(nxt, nxt_node, new, from_cfg,
                                        flatten=(node[0] == "conv"))
        else:
            tag = "/".join(map(str, node))
            mapping = nc.dup_mapping(new, old, tag=tag, seed=seed)
            layer["w"] = nc.narrow_fold_in(layer["w"], mapping, new, axis=out_axis)
            layer["b"] = nc.narrow_fold_in(layer["b"], mapping, new, axis=0)
            nxt = _widen_next_in(nxt, nxt_node, mapping, new, from_cfg,
                                 fold=True, flatten=(node[0] == "conv"))
        _set(params, node, layer)
        _set(params, nxt_node, nxt)

    # --- To-Shallower: drop trailing convs per stage
    for si, ws_to in enumerate(to_cfg.stages):
        stage = params["stages"][f"s{si}"]
        for li in range(len(ws_to), len(from_cfg.stages[si])):
            del stage[f"c{li}"]
    return params
