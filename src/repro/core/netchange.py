"""NetChange — FedADP's structure-transformation primitives (paper §III.B).

Four transforms move a model between architectures of the same family:

  To-Wider   (Alg. 2)  new neurons duplicate randomly-chosen existing ones;
                       each duplicate group's OUTGOING weights are divided
                       by the group size  => function preserving (Net2Net).
  To-Deeper            insert missing layers initialized to identity
                       (diagonal 1 / zero elsewhere for plain stacks;
                       zero-output-projection for pre-norm residual blocks).
  To-Narrower (Alg. 3) delete neurons beyond N_tar; the summed outgoing
                       weights of deleted neurons are redistributed evenly
                       (s / N_tar added to each survivor)  => lossy.
  To-Shallower         drop the layers the target doesn't have.

Interpretation notes (recorded for faithfulness):
  * Alg. 2's "value v_i" division is applied to outgoing weights — the
    Net2Net semantics the paper extends and whose function preservation
    the paper asserts ("the output of the expanded layer remains
    unchanged").
  * Alg. 3's redistribution is applied to outgoing weight rows ("their
    associated weights are evenly redistributed among the remaining
    neurons"); incoming columns of deleted neurons are removed.

Beyond paper: ``narrow_fold`` — the exact inverse of To-Wider given the
expansion mapping (mean incoming copies, sum outgoing splits). Function
preserving when duplicate groups stayed identical; compared against
Alg. 3 in ablations (EXPERIMENTS.md).

Mappings are deterministic in (tag, old_width, new_width, seed) so the
server and clients derive identical expansions without communication.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------- mappings

NARROW_MODES = ("paper", "fold")


def round_embed_seed(base_seed: int, round_idx: int, k: int) -> int:
    """The per-(round, client) NetChange seed — ONE formula shared by the
    per-client loop (``FedADP._seed``) and the unified engine, so both
    paths draw identical To-Wider duplication mappings. The distribute
    fold and collect widen of a round are mutual inverses because they
    share this seed."""
    return (base_seed * 1_000_003 + round_idx * 997 + k) % (2 ** 31)


class KeyedCache:
    """Bounded get-or-build LRU for seed-keyed embedding artifacts
    (coverage masks, segment matrices, packed coverage/multiplicity
    rows): per-round seeds are unbounded over a run's lifetime, so the
    maps must evict. ONE cache class, one sizing knob — ``max(128,
    4·n_clients)`` entries by default, so one round of a big cohort
    never evicts itself — shared by ``FedADP`` and ``UnifiedEngine``
    (keys are namespaced tuples, e.g. ``("cov", k, seed)``), so the
    loop and engine seed caches cannot diverge. ``stats()`` exposes
    hit/miss/size/bound counters for tests and ops dashboards."""

    def __init__(self, *, n_clients: int = 0, bound: Optional[int] = None):
        self.bound = bound if bound is not None else max(128, 4 * n_clients)
        self._d: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key, build):
        if key in self._d:
            self.hits += 1
            self._d.move_to_end(key)
            return self._d[key]
        self.misses += 1
        val = self._d[key] = build()
        while len(self._d) > self.bound:
            self._d.popitem(last=False)
        return val

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._d), "bound": self.bound}

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d


def dup_mapping(old: int, new: int, *, tag: str = "", seed: int = 0) -> np.ndarray:
    """Mapping m: [new] -> [old]. First ``old`` slots are the identity; the
    remaining ``new - old`` duplicate sources are chosen uniformly (Alg. 2
    line 6, "randomly select neuron j") from a deterministic stream."""
    assert new >= old > 0, (old, new)
    h = int.from_bytes(hashlib.sha256(f"{tag}:{old}:{new}:{seed}".encode())
                       .digest()[:8], "big")
    rng = np.random.default_rng(h)
    extra = rng.integers(0, old, size=new - old)
    return np.concatenate([np.arange(old), extra]).astype(np.int32)


def mapping_counts(mapping: np.ndarray, old: int) -> np.ndarray:
    return np.bincount(mapping, minlength=old).astype(np.int32)


def head_to_unit_mapping(head_map: np.ndarray, unit: int) -> np.ndarray:
    """Lift a mapping over groups (heads/experts) to element granularity."""
    return (head_map[:, None] * unit + np.arange(unit)[None, :]).reshape(-1)


# -------------------------------------------------------------- To-Wider

def widen_in(w, mapping, axis: int = -1):
    """Incoming weights: duplicate columns per ``mapping`` (Alg. 2 l.7-8)."""
    return jnp.take(w, jnp.asarray(mapping), axis=axis)


def widen_out(w, mapping, old: int, axis: int = 0):
    """Outgoing weights: duplicate rows and divide each duplicate group by
    its size (Alg. 2 l.11-14)."""
    counts = mapping_counts(np.asarray(mapping), old)
    scale = (1.0 / counts[np.asarray(mapping)]).astype(np.float32)
    out = jnp.take(w, jnp.asarray(mapping), axis=axis)
    shape = [1] * out.ndim
    shape[axis] = -1
    return (out * jnp.asarray(scale).reshape(shape).astype(out.dtype))


# ------------------------------------------------------------- To-Narrower

def narrow_in(w, n_tar: int, axis: int = -1):
    """Incoming weights: drop columns of deleted neurons (> N_tar)."""
    return jax.lax.slice_in_dim(w, 0, n_tar, axis=axis)


def narrow_out_paper(w, n_tar: int, axis: int = 0):
    """Alg. 3: s = sum of deleted rows; survivors += s / N_tar."""
    kept = jax.lax.slice_in_dim(w, 0, n_tar, axis=axis)
    dropped = jax.lax.slice_in_dim(w, n_tar, w.shape[axis], axis=axis)
    s = dropped.sum(axis=axis, keepdims=True)
    return kept + (s / n_tar).astype(kept.dtype)


def narrow_fold_in(w, mapping, old: int, axis: int = -1):
    """Beyond-paper inverse of ``widen_in``: mean over each duplicate group."""
    m = jnp.asarray(mapping)
    counts = jnp.asarray(mapping_counts(np.asarray(mapping), old))
    w_moved = jnp.moveaxis(w, axis, 0)
    summed = jax.ops.segment_sum(w_moved, m, num_segments=old)
    mean = summed / counts.reshape((-1,) + (1,) * (summed.ndim - 1)).astype(w.dtype)
    return jnp.moveaxis(mean, 0, axis)


def narrow_fold_out(w, mapping, old: int, axis: int = 0):
    """Beyond-paper inverse of ``widen_out``: sum over each duplicate group."""
    m = jnp.asarray(mapping)
    w_moved = jnp.moveaxis(w, axis, 0)
    summed = jax.ops.segment_sum(w_moved, m, num_segments=old)
    return jnp.moveaxis(summed, 0, axis)


# ----------------------------------------------------- To-Deeper (identity)

def identity_conv(channels: int, ksize: int = 3, dtype=jnp.float32):
    """3x3 conv kernel acting as identity (center tap = channel diagonal).
    Exact identity after ReLU since preceding activations are >= 0."""
    w = jnp.zeros((ksize, ksize, channels, channels), dtype)
    c = ksize // 2
    return w.at[c, c].set(jnp.eye(channels, dtype=dtype))


def identity_fc(width: int, dtype=jnp.float32):
    return jnp.eye(width, dtype=dtype)


def zero_like_output_proj(params, out_proj_keys: Sequence[str]):
    """Pre-norm residual identity insert: zero the block's output
    projections so the residual branch contributes nothing."""
    def fix(path, leaf):
        names = [getattr(k, "key", None) for k in path]
        return jnp.zeros_like(leaf) if any(n in out_proj_keys for n in names) else leaf
    return jax.tree_util.tree_map_with_path(fix, params)
