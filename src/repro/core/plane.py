"""Packed parameter plane — ONE contiguous layout for a whole cohort.

FedADP's aggregation math (Eq. 1-2, coverage averaging) is per-coordinate
and layout-agnostic: nothing in ``Σ_k W_k m_kj x_kj`` cares which leaf a
coordinate came from. Yet every layer above the kernels used to walk the
union pytree leaf-by-leaf — one kernel dispatch per leaf, four parallel
trees (masks / multiplicity / filler / fallback) gathered and validated
per round. This module packs the union tree into a single contiguous
``(K, P)`` f32 *plane* plus a static, hashable :class:`PlaneSpec`
describing where each leaf lives, so

  * a cohort aggregates in ONE tiled kernel pass over the plane
    (``kernels/fedavg.plane_agg`` — grid over P-tiles),
  * the four parallel trees become four row-aligned planes, built once
    per (cohort, seed),
  * participant gathers become row slices (``plane[idx]``) instead of
    per-leaf tree gathers,
  * round state stays packed across the whole round and the jitted step
    can donate the plane buffers.

Dtype contract: the plane is always f32 — packing casts each leaf up,
unpacking casts back to the leaf's recorded dtype (bf16 leaves ride the
plane as exact f32 embeddings; accumulate in f32, cast back).
``requantize`` reproduces the per-leaf storage rounding (cast through the
leaf dtype and back) for paths that must match the tree-shaped reference
step-for-step; it is a static no-op on all-f32 cohorts.

``pack``/``unpack`` are pure jnp reshape/concat/slice — inside ``jit``
they fuse away, so "packed" costs nothing at trace boundaries. The spec
is hashable and equality-comparable, which makes it a valid static jit
argument (``core.aggregation._plane_pass`` keys its compile cache on it).

Ragged input raises ``ValueError`` naming the offending leaf path and the
two mismatched shapes — the same message contract
``aggregation.stack_trees`` uses (``ragged_leaf_error``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.segments import path_keys

Path = Tuple[str, ...]

_F32 = "float32"


def ragged_leaf_error(what: str, path, got, want) -> ValueError:
    """The ONE ragged-input message contract: name the leaf path and the
    two mismatched shapes (shared by ``stack_trees`` and ``PlaneSpec``)."""
    name = "/".join(path) if isinstance(path, tuple) else str(path)
    return ValueError(
        f"{what}: leaf '{name}' has shape {tuple(got)}, expected "
        f"{tuple(want)} — trees must agree leaf-by-leaf")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_keys(p), leaf) for p, leaf in flat], treedef


@dataclass(frozen=True)
class PlaneSpec:
    """Static description of a packed plane: for each leaf (in flatten
    order) its path, shape (WITHOUT the stacked K axis), dtype and column
    offset. Hashable — safe as a static jit argument and as a cache key;
    two specs are equal iff the packed layout is identical."""
    paths: Tuple[Path, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    offsets: Tuple[int, ...]
    size: int                    # P: total packed coordinates
    treedef: Any                 # jax PyTreeDef (hashable)

    # ------------------------------------------------------- constructors
    @classmethod
    def _build(cls, items, treedef) -> "PlaneSpec":
        paths, shapes, dtypes, offsets = [], [], [], []
        off = 0
        for path, shape, dtype in items:
            paths.append(path)
            shapes.append(tuple(int(s) for s in shape))
            dtypes.append(str(dtype))
            offsets.append(off)
            off += int(np.prod(shape)) if shape else 1
        return cls(tuple(paths), tuple(shapes), tuple(dtypes),
                   tuple(offsets), off, treedef)

    @classmethod
    def from_tree(cls, tree) -> "PlaneSpec":
        """Spec of an un-stacked tree (arrays or ShapeDtypeStructs)."""
        flat, treedef = _flatten(tree)
        if not flat:
            raise ValueError("PlaneSpec: tree has no leaves")
        return cls._build([(p, l.shape, l.dtype) for p, l in flat], treedef)

    @classmethod
    def from_stacked(cls, stacked) -> Tuple["PlaneSpec", int]:
        """Spec of a stacked tree (every leaf ``(K, ...)``); returns
        ``(spec, K)`` with the K axis stripped from the recorded shapes.
        Ragged leading axes raise naming the offending leaf path."""
        flat, treedef = _flatten(stacked)
        if not flat:
            raise ValueError("PlaneSpec: tree has no leaves")
        k = None
        items = []
        for path, leaf in flat:
            if leaf.ndim < 1:
                raise ragged_leaf_error("PlaneSpec.from_stacked", path,
                                        leaf.shape, ("K", "..."))
            if k is None:
                k = int(leaf.shape[0])
            elif int(leaf.shape[0]) != k:
                raise ragged_leaf_error(
                    "PlaneSpec.from_stacked", path, leaf.shape,
                    (k,) + tuple(leaf.shape[1:]))
            items.append((path, leaf.shape[1:], leaf.dtype))
        return cls._build(items, treedef), k

    # -------------------------------------------------------- inspection
    @property
    def n_leaves(self) -> int:
        return len(self.paths)

    @property
    def all_f32(self) -> bool:
        return all(d == _F32 for d in self.dtypes)

    def leaf_sizes(self) -> Tuple[int, ...]:
        return tuple(int(np.prod(s)) if s else 1 for s in self.shapes)

    def col_mask(self, pred) -> np.ndarray:
        """0/1 ``(P,)`` f32 column mask selecting every leaf whose path
        tuple satisfies ``pred`` — leaf-granular plane algebra (e.g. the
        FlexiFed common-prefix columns) without touching the tree."""
        out = np.zeros((self.size,), np.float32)
        for path, off, n in zip(self.paths, self.offsets,
                                self.leaf_sizes()):
            if pred(path):
                out[off:off + n] = 1.0
        return out

    def validate(self, tree, *, what: str = "tree", stacked: bool = False,
                 check_dtypes: bool = False):
        """Check ``tree`` matches this layout leaf-by-leaf; raises the
        ragged-leaf contract error naming the path and both shapes.

        ``check_dtypes`` stays opt-in: packing casts everything to f32,
        so mask/multiplicity planes are legitimately built from f32
        trees against specs recording bf16 leaf dtypes. Checkpoint and
        manifest loaders, where the storage dtype IS the contract, pass
        ``check_dtypes=True``."""
        flat, _ = _flatten(tree)
        if len(flat) != self.n_leaves:
            raise ValueError(
                f"{what}: {len(flat)} leaves, expected {self.n_leaves}")
        for (path, leaf), spath, sshape, sdtype in zip(flat, self.paths,
                                                       self.shapes,
                                                       self.dtypes):
            if path != spath:
                raise ValueError(f"{what}: leaf '{'/'.join(path)}' where "
                                 f"'{'/'.join(spath)}' was expected — "
                                 "tree structure does not match the spec")
            got = tuple(leaf.shape)
            if stacked:
                if len(got) < 1 or got[1:] != sshape:
                    raise ragged_leaf_error(what, path, got,
                                            ("K",) + sshape)
            elif got != sshape:
                raise ragged_leaf_error(what, path, got, sshape)
            if check_dtypes and str(leaf.dtype) != sdtype:
                raise ValueError(
                    f"{what}: leaf '{'/'.join(path)}' has dtype "
                    f"{leaf.dtype}, expected {sdtype} — storage dtypes "
                    "must match the spec")
        return flat

    # ------------------------------------------------------- serialization
    def to_manifest(self) -> Dict[str, Any]:
        """JSON-serializable layout (treedef reconstructed as nested
        dicts on load — models in this repo are plain dict pytrees)."""
        return {"paths": ["/".join(p) for p in self.paths],
                "shapes": [list(s) for s in self.shapes],
                "dtypes": list(self.dtypes)}

    @classmethod
    def from_manifest(cls, man: Dict[str, Any]) -> "PlaneSpec":
        nested: Dict[str, Any] = {}
        for path, shape, dtype in zip(man["paths"], man["shapes"],
                                      man["dtypes"]):
            cur = nested
            parts = path.split("/")
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = jax.ShapeDtypeStruct(tuple(shape),
                                                  jnp.dtype(dtype))
        return cls.from_tree(nested)


# ----------------------------------------------------------------- packing
def pack(tree, spec: PlaneSpec, *, what: str = "pack") -> jnp.ndarray:
    """Flatten an un-stacked tree into a contiguous ``(P,)`` f32 plane in
    the spec's layout (validates paths + shapes, error names the leaf)."""
    flat = spec.validate(tree, what=what)
    return jnp.concatenate([
        jnp.asarray(leaf).reshape(-1).astype(jnp.float32)
        for _, leaf in flat])


def pack_stacked(stacked, spec: PlaneSpec, *,
                 what: str = "pack_stacked") -> jnp.ndarray:
    """Flatten a stacked tree (leaves ``(K, ...)``) into a ``(K, P)`` f32
    plane; rows are clients, columns follow the spec layout."""
    flat = spec.validate(stacked, what=what, stacked=True)
    k = int(flat[0][1].shape[0])
    for path, leaf in flat:
        if int(leaf.shape[0]) != k:
            raise ragged_leaf_error(what, path, leaf.shape,
                                    (k,) + tuple(leaf.shape[1:]))
    return jnp.concatenate([
        jnp.asarray(leaf).reshape(k, -1).astype(jnp.float32)
        for _, leaf in flat], axis=1)


def pack_trees(trees: Sequence, spec: PlaneSpec, *,
               what: str = "pack_trees") -> jnp.ndarray:
    """Pack a list of un-stacked trees into a row-aligned ``(K, P)``
    plane (row k = tree k) — ``stack_trees`` + ``pack_stacked`` fused."""
    return jnp.stack([pack(t, spec, what=f"{what}[{i}]")
                      for i, t in enumerate(trees)])


def unpack(plane: jnp.ndarray, spec: PlaneSpec):
    """``(P,)`` plane -> tree, restoring each leaf's shape and dtype."""
    leaves = [plane[o:o + n].reshape(s).astype(jnp.dtype(d))
              for o, n, s, d in zip(spec.offsets, spec.leaf_sizes(),
                                    spec.shapes, spec.dtypes)]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def unpack_stacked(plane: jnp.ndarray, spec: PlaneSpec):
    """``(K, P)`` plane -> stacked tree (leading K on every leaf)."""
    k = plane.shape[0]
    leaves = [plane[:, o:o + n].reshape((k,) + s).astype(jnp.dtype(d))
              for o, n, s, d in zip(spec.offsets, spec.leaf_sizes(),
                                    spec.shapes, spec.dtypes)]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def requantize(plane: jnp.ndarray, spec: PlaneSpec) -> jnp.ndarray:
    """Round the plane's columns through their leaf storage dtypes (cast
    down, cast back to f32) so packed training matches the tree-shaped
    reference's per-step storage rounding. Static no-op when every leaf
    is f32 — the common case costs nothing."""
    if spec.all_f32:
        return plane
    pieces = []
    for o, n, d in zip(spec.offsets, spec.leaf_sizes(), spec.dtypes):
        seg = plane[..., o:o + n]
        if d != _F32:
            seg = seg.astype(jnp.dtype(d)).astype(jnp.float32)
        pieces.append(seg)
    return jnp.concatenate(pieces, axis=-1)


# ----------------------------------------------------- streaming helpers
def chunk_bounds(k: int, k_chunk: int) -> Tuple[Tuple[int, int], ...]:
    """Row-chunk bounds ``((lo, hi), ...)`` covering ``k`` rows in
    ``k_chunk``-sized chunks (last chunk ragged when ``k_chunk`` does not
    divide ``k``). The ONE chunking rule every streaming consumer shares
    — equal chunk sizes are what keep the engine's per-size jitted step
    cache at one entry per round shape."""
    if k_chunk < 1:
        raise ValueError(f"k_chunk={k_chunk!r} must be >= 1")
    k_chunk = min(k_chunk, k)
    return tuple((lo, min(lo + k_chunk, k)) for lo in range(0, k, k_chunk))


def stacked_rows(stacked, lo: int, hi: int):
    """Row-slice a stacked tree: every leaf ``(K, ...)`` ->
    ``(hi - lo, ...)`` — the tree-level view of a plane row chunk."""
    return jax.tree.map(lambda a: a[lo:hi], stacked)


# ------------------------------------------------- packed cohort builders
def cohort_planes(family, client_cfgs: Sequence, global_cfg, *,
                  seed: int = 0, coverage: str = "loose"):
    """The four parallel per-client trees of a cohort embedding — strict
    mask, filler, aggregation-coverage mask, multiplicity — as four
    row-aligned ``(K, P)`` planes built ONCE per (cohort, seed), plus the
    spec. Multiplicity is ``None`` for families without segment metadata
    (depth-only semantics: every count is 1)."""
    from repro.core.aggregation import (coverage_and_filler, global_shapes,
                                        loosen, multiplicity)
    spec = PlaneSpec.from_tree(global_shapes(family, global_cfg))
    masks, fillers, covs, mults = [], [], [], []
    spec_fn = getattr(family, "segment_spec", None)
    for cfg in client_cfgs:
        m, f = coverage_and_filler(family, cfg, global_cfg, seed=seed)
        masks.append(pack(m, spec, what="cohort_planes/mask"))
        fillers.append(pack(f, spec, what="cohort_planes/filler"))
        cov = m if coverage == "strict" else loosen(m, f)
        covs.append(pack(cov, spec, what="cohort_planes/cov"))
        if spec_fn is not None:
            mults.append(pack(multiplicity(family, cfg, global_cfg,
                                           seed=seed),
                              spec, what="cohort_planes/mult"))
    return (spec, jnp.stack(masks), jnp.stack(fillers), jnp.stack(covs),
            jnp.stack(mults) if mults else None)
