"""Sharding rules: parameter/cache paths -> PartitionSpec.

Strategy (DESIGN.md §5): tensor-parallel over ``model`` (heads / d_ff /
experts / recurrent channels / vocab), FSDP over the data axes for the
d_model dimension of large matrices, batch over the data axes. A dimension
that is not divisible by its assigned mesh extent falls back to
replication (e.g. tiny head counts in reduced configs).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = "__data__"          # placeholder replaced by the mesh's data axes
MP = "model"

# (path regex, spec template over the LAST len(template) dims; leading dims
# -- the scan-stack axis, expert axis handled explicitly -- are replicated)
_PARAM_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"embed$", (MP, DP)),
    (r"lm_head$", (DP, MP)),
    (r"attn/(wq|wk|wv)$", (DP, MP)),
    (r"attn/(bq|bk|bv)$", (MP,)),
    (r"attn/wo$", (MP, DP)),
    (r"xattn/(wq|wk|wv)$", (DP, MP)),
    (r"xattn/wo$", (MP, DP)),
    (r"attn/(wq_a|wkv_a)$", (DP, MP)),          # MLA down-projections
    (r"attn/(wq_b|wkv_b)$", (None, MP)),        # lora rank small: replicate
    (r"attn/(qln|kvln)$", (None,)),
    (r"(mlp|shared)/(wg|wu|wi)$", (DP, MP)),
    (r"(mlp|shared)/bi$", (MP,)),
    (r"(mlp|shared)/wd$", (MP, DP)),
    (r"(mlp|shared)/bd$", (None,)),
    (r"moe/router$", (None, None)),
    (r"moe/router_b$", (None,)),
    # E -> model (expert parallel) when E divides the model axis; otherwise
    # F -> model (tensor parallel inside each expert). Resolved dynamically
    # in ``_moe_spec`` — these templates are the expert-parallel default.
    (r"moe/(wg|wu)$", (MP, DP, None)),
    (r"moe/wd$", (MP, None, DP)),
    (r"rg/(win|wgate)$", (DP, MP)),
    (r"rg/conv$", (None, MP)),
    (r"rg/(ba|bx|lam)$", (MP,)),
    (r"rg/(wa|wx)$", (DP, MP)),
    (r"rg/wout$", (MP, DP)),
    (r"mx/(wup|wz|wq|wk|wv)$", (DP, MP)),
    (r"mx/conv$", (None, MP)),
    (r"mx/(wi|wf)$", (DP, None)),
    (r"mx/(bi|bf)$", (None,)),
    (r"mx/gn$", (MP,)),
    (r"mx/wdown$", (MP, DP)),
    (r"sx/(w[zifo])$", (DP, MP)),
    (r"sx/(b[zifo]|bf_init|gn)$", (MP,)),
    (r"sx/(r[zifo])$", (None, None, None)),     # (H, dh, dh): H tiny
    (r"sx/wout$", (DP, MP)),
    (r"(ln1|ln2|lnx|final_ln)$", (None,)),
)

# cache / state leaves (base shapes, before the stacked-units axis):
#   attention k/v    (B, S, KV, hd)
#   mla ckv          (B, S, r)   krope (B, S, rope)
#   cross xk/xv      (B, T, H, hd)
#   rg h             (B, R)      rg conv (B, cw-1, R)
#   mlstm C          (B, H, dh, dh)   n (B, H, dh)  m (B, H)  conv (B,cw-1,Dm)
#   slstm c/n/m/h    (B, D)
_CACHE_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"/(k|v)$", (DP, "__seq__", MP, None)),
    (r"/(xk|xv)$", (DP, None, MP, None)),
    (r"/ckv$", (DP, "__seq__", None)),
    (r"/krope$", (DP, "__seq__", None)),
    (r"conv$", (DP, None, MP)),
    (r"/C$", (DP, None, None, None)),
    (r"/(n|m)$", (DP, None, None)),
    (r"/(c|h)$", (DP, MP)),
)


def _resolve(template: Tuple, shape: Tuple[int, ...], mesh: Mesh,
             data_axes: Tuple[str, ...], *, shard_seq: bool,
             align: str = "right", stack_offset: int = 0) -> P:
    """Apply a spec template to ``shape``. Params align right (templates
    describe trailing dims under a stacked-units axis); caches align left
    starting after ``stack_offset`` leading axes."""
    ndim = len(shape)
    entries: list = [None] * ndim
    if align == "right":
        off = ndim - len(template)
        assert off >= 0, (template, shape)
        pairs = [(off + i, t) for i, t in enumerate(template)]
    else:
        pairs = [(stack_offset + i, t) for i, t in enumerate(template)
                 if stack_offset + i < ndim]
    for dim, t in pairs:
        if t is None:
            continue
        if t == "__seq__":
            if shard_seq and data_axes:
                t = DP
            else:
                continue
        axes = data_axes if t == DP else (t,)
        if not axes:
            continue
        extent = int(np.prod([mesh.shape[a] for a in axes]))
        if shape[dim] % extent == 0 and shape[dim] > 0:
            entries[dim] = axes if len(axes) > 1 else axes[0]
    return P(*entries)


def _match(path: str, rules) -> Optional[Tuple]:
    for pat, tpl in rules:
        if re.search(pat, path):
            return tpl
    return None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _moe_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
              data_axes: Tuple[str, ...]) -> Optional[Tuple]:
    """Expert stacks: expert-parallel when E divides the model axis, else
    tensor-parallel on the expert F dim."""
    m = re.search(r"moe/(wg|wu|wd)$", path)
    if not m:
        return None
    n_experts = shape[-3]
    if n_experts % mesh.shape[MP] == 0:
        return (MP, DP, None) if m.group(1) in ("wg", "wu") else (MP, None, DP)
    return (None, DP, MP) if m.group(1) in ("wg", "wu") else (None, MP, DP)


def param_specs(params, mesh: Mesh, data_axes: Tuple[str, ...], *,
                embed_tp: bool = False):
    """PartitionSpec tree for a parameter pytree (shapes or arrays).

    embed_tp: shard the embedding (vocab -> model, d_model replicated)
    instead of (vocab -> model, d_model -> data). The FSDP layout makes
    every loss-chunk logit matmul contract over a data-sharded d_model
    (an all-reduce per chunk); the TP layout pays one embedding-lookup
    psum per step instead — §Perf iteration 1."""
    def one(path, leaf):
        s = _path_str(path)
        if embed_tp and re.search(r"(^|/)(embed|lm_head)$", s):
            tpl = (MP, None) if s.endswith("embed") else (None, MP)
            return _resolve(tpl, tuple(leaf.shape), mesh, data_axes,
                            shard_seq=False)
        tpl = _moe_spec(s, tuple(leaf.shape), mesh, data_axes)
        if tpl is None:
            tpl = _match(s, _PARAM_RULES)
        if tpl is None:
            return P()
        return _resolve(tpl, tuple(leaf.shape), mesh, data_axes,
                        shard_seq=False)
    return jax.tree_util.tree_map_with_path(one, params)


def cache_specs(cache, mesh: Mesh, data_axes: Tuple[str, ...], *,
                batch_shardable: bool):
    """PartitionSpec tree for a decode cache. When the batch is too small
    to shard (long_500k, B=1) the sequence dim is sharded over data
    instead (``__seq__`` entries)."""
    def one(path, leaf):
        s = _path_str(path)
        tpl = _match(s, _CACHE_RULES)
        if tpl is None:
            return P()
        stacked = s.startswith("units")
        return _resolve(tpl, tuple(leaf.shape), mesh, data_axes,
                        shard_seq=not batch_shardable, align="left",
                        stack_offset=1 if stacked else 0)
    # when the batch is shardable we shard batch (DP) and leave seq whole;
    # otherwise DP entries fail divisibility (B=1) and seq takes the axes.
    return jax.tree_util.tree_map_with_path(one, cache)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def stacked_client_spec(mesh: Mesh, client_axes: Tuple[str, ...],
                        n_clients: int) -> P:
    """Spec for unified-cohort trees whose leaves carry a leading K (client)
    axis (DESIGN.md §5): shard K over ``client_axes``, replicate the rest.
    Falls back to replication when K does not divide the mesh extent —
    the same divisibility rule ``_resolve`` applies to parameter dims."""
    if not client_axes:
        return P()
    extent = int(np.prod([mesh.shape[a] for a in client_axes]))
    if extent <= 1 or n_clients % extent != 0:
        return P()
    return P(client_axes if len(client_axes) > 1 else client_axes[0])


def cohort_mesh(n_clients: int, *, axis: str = "clients") -> Optional[Mesh]:
    """1-D device mesh for sharding a K-client unified cohort. Uses the
    largest device count that divides K (devices beyond it are left idle);
    returns None when only one device would participate."""
    devs = jax.devices()
    n = len(devs)
    while n > 1 and n_clients % n != 0:
        n -= 1
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), (axis,))
