"""Distribution context threaded (statically) through model code.

``ShardCtx`` tells the model which mesh axes exist so that layers with an
explicit distribution strategy (the MoE expert-parallel block) can use
``shard_map`` + collectives, while single-device paths (CPU smoke tests)
run the identical math locally.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from jax.sharding import Mesh, PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ()     # batch axes, e.g. ("pod", "data")
    model_axis: Optional[str] = None    # tensor/expert-parallel axis
    # perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    attn_backend: str = "auto"          # "auto" | "flash" | "blockwise":
                                        # auto = flash Pallas kernel on TPU,
                                        # blockwise XLA path elsewhere
    banded_local: bool = True           # banded blockwise attn for local layers
    causal_skip: bool = False           # skip fully-masked kv blocks (causal)
    mla_absorb: bool = False            # absorbed MLA decode (w_kv_b folded)
    moe_all_to_all: bool = False        # a2a dispatch instead of psum combine
    block_q: int = 512
    block_kv: int = 512
    remat: bool = False                 # checkpoint each layer unit
    remat_policy: str = "full"          # full | dots (save matmul outputs)
    embed_tp: bool = False              # embed: (model, None) instead of
                                        # (model, data) — kills the per-
                                        # loss-chunk logit all-reduce
    tp_bf16_reduce: bool = False        # row-parallel projections reduce
                                        # partial sums in bf16 via shard_map
                                        # (XLA's default AR is f32 — 2x bytes)
    seq_parallel: bool = False          # Megatron-style sequence parallelism:
                                        # residual stream sharded S->model
                                        # between blocks (AR -> RS + AG)

    @property
    def distributed(self) -> bool:
        return self.mesh is not None and self.model_axis is not None

    @property
    def model_size(self) -> int:
        if not self.distributed:
            return 1
        return self.mesh.shape[self.model_axis]

    def batch_spec(self, *rest) -> P:
        lead = self.data_axes if self.data_axes else None
        return P(lead, *rest)


CPU_CTX = ShardCtx()


@dataclass(frozen=True)
class CohortCtx:
    """Client-axis distribution context of the unified FL engine
    (DESIGN.md §5, §9): which mesh axes the cohort's K (plane-row) axis
    shards over, and the streaming chunk pin. One frozen object threads
    the engine's two scaling mechanisms — ``row_spec`` drives both the
    shard-mapped training step and the two-level edge reduce (each mesh
    slot of the client axes is one "edge" sub-cohort), ``k_chunk`` pins
    the O(P·k_chunk) streaming aggregation."""
    mesh: Optional[Mesh] = None
    client_axes: Tuple[str, ...] = ("clients",)
    k_chunk: Optional[int] = None       # streaming rows (None = auto)

    @property
    def edge_extent(self) -> int:
        """How many edge reducers the client axes hold (1 = no mesh)."""
        if self.mesh is None or not self.client_axes:
            return 1
        ext = 1
        for a in self.client_axes:
            ext *= int(self.mesh.shape[a])
        return ext

    def row_spec(self, n_rows: int) -> P:
        """Spec for ``(n_rows, ...)`` cohort planes/trees: K over the
        client axes, replicated when it doesn't divide (the rules.py
        divisibility convention)."""
        if self.mesh is None:
            return P()
        from repro.sharding.rules import stacked_client_spec
        return stacked_client_spec(self.mesh, self.client_axes, n_rows)

    def edge_groups(self, ks) -> list:
        """The two-level reduce's sub-cohorts: the participating client
        ids split contiguously, one group per mesh slot of the client
        axes — exactly the rows ``row_spec`` lands on each device. With
        no (usable) mesh the whole cohort is one group."""
        ks = list(ks)
        e = self.edge_extent
        if e <= 1 or len(ks) % e != 0:
            return [ks]
        step = len(ks) // e
        return [ks[i * step:(i + 1) * step] for i in range(e)]
