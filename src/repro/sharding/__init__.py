from repro.sharding.ctx import CPU_CTX, ShardCtx  # noqa: F401
