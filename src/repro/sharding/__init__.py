from repro.sharding.ctx import CPU_CTX, ShardCtx  # noqa: F401
from repro.sharding.rules import (  # noqa: F401
    cache_specs, cohort_mesh, named, param_specs, stacked_client_spec)
