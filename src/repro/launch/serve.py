"""Serving launcher: batched prefill + decode for any registered arch.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import transformer as T
from repro.sharding.ctx import CPU_CTX


def run(arch: str, *, use_reduced: bool = True, batch: int = 4,
        prompt_len: int = 32, gen: int = 16, seed: int = 0,
        temperature: float = 0.0):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    key, k_init, k_aux, k_prompt = jax.random.split(
        jax.random.PRNGKey(seed), 4)
    params = T.init_params(k_init, cfg)
    npx = (cfg.frontend.n_prefix
           if cfg.frontend is not None and cfg.frontend.kind == "vision" else 0)
    cache_len = npx + prompt_len + gen

    aux = None
    if npx:
        aux = jax.random.normal(k_aux, (batch, npx, cfg.d_model),
                                dtype=cfg.dtype)
    elif cfg.encoder is not None:
        aux = jax.random.normal(k_aux, (batch, cfg.encoder.n_ctx, cfg.d_model),
                                dtype=cfg.dtype)

    prefill = jax.jit(make_prefill_step(cfg, ctx=CPU_CTX, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg, ctx=CPU_CTX))

    prompts = jax.random.randint(k_prompt, (batch, prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    b = {"tokens": prompts}
    if aux is not None:
        b["aux"] = aux
    logits, cache = prefill(params, b)
    t_prefill = time.time() - t0

    toks = []
    tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    t1 = time.time()
    for i in range(gen):
        toks.append(tok)
        logits, cache = decode(params, tok, cache,
                               jnp.int32(npx + prompt_len + i))
        if temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(sk, logits / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    out = jnp.concatenate(toks, axis=1)
    t_dec = time.time() - t1
    print(f"arch={cfg.name} prefill({batch}x{prompt_len})={t_prefill*1e3:.0f}ms "
          f"decode {gen} toks={t_dec*1e3:.0f}ms "
          f"({t_dec/gen*1e3:.1f} ms/tok incl. compile)")
    print("sample tokens:", np.asarray(out[0][:12]))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    run(args.arch, use_reduced=args.reduced, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen,
        temperature=args.temperature)


if __name__ == "__main__":
    main()
