"""Step functions the launcher (and the dry-run) lowers.

  train_step    — AdamW/SGD LM step (train_4k)
  prefill_step  — build KV cache from a prompt, last-token logits (prefill_32k)
  decode_step   — one token against an S-entry cache (decode_32k, long_500k)

The cross-entropy is computed in vocab chunks (``loss_chunk``) so the
(B, S, V) logits tensor of large-vocab models is never materialized —
see EXPERIMENTS.md §Perf for the before/after.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.sharding.ctx import CPU_CTX, ShardCtx


def _text_hidden(params, cfg, h):
    """Drop vision-prefix positions so hidden rows align with labels."""
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        return h[:, cfg.frontend.n_prefix:]
    return h


def chunked_softmax_xent(h, w, labels, *, chunk: int = 0):
    """Mean next-token CE without materializing (B,S,V) at once.

    h: (B,S,D); w: (D,V); labels: (B,S) int32. chunk = sequence-chunk size
    (0 => single chunk, i.e. the unchunked baseline)."""
    B, S, D = h.shape
    if chunk <= 0 or chunk >= S:
        logits = (h @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return (lse - ll).mean(), logits.argmax(-1)

    n = -(-S // chunk)
    pad = n * chunk - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    mask = jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    hc = hp.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = lp.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hi, li, mi = xs
        logits = (hi @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        hits = (logits.argmax(-1) == li).astype(jnp.float32) * mi
        return (acc[0] + ((lse - ll) * mi).sum(), acc[1] + hits.sum()), None

    (total, hits), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                    (hc, lc, mc))
    return total / (B * S), hits / (B * S)


def lm_loss(params, cfg: ModelConfig, batch, *, ctx: ShardCtx = CPU_CTX,
            loss_chunk: int = 0):
    """batch: {'tokens': (B,S), 'labels': (B,S), ['aux': modality embeds]}."""
    h = T.forward_hidden(params, cfg, batch["tokens"], ctx=ctx,
                         aux=batch.get("aux"))
    h = _text_hidden(params, cfg, h)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    loss, aux = chunked_softmax_xent(h, w, batch["labels"], chunk=loss_chunk)
    return loss, {"acc_or_preds": aux}


def make_train_step(cfg: ModelConfig, optimizer, *, ctx: ShardCtx = CPU_CTX,
                    loss_chunk: int = 0):
    def train_step(params, opt_state, step, batch):
        (loss, _), grads = jax.value_and_grad(lm_loss, has_aux=True)(
            params, cfg, batch, ctx=ctx, loss_chunk=loss_chunk)
        new_params, new_state = optimizer.update(grads, opt_state, params, step)
        return new_params, new_state, {"loss": loss}
    return train_step


def make_prefill_step(cfg: ModelConfig, *, ctx: ShardCtx = CPU_CTX,
                      cache_len: Optional[int] = None):
    def prefill_step(params, batch):
        logits, cache = T.prefill(params, cfg, batch["tokens"], ctx=ctx,
                                  aux=batch.get("aux"), cache_len=cache_len)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, ctx: ShardCtx = CPU_CTX):
    def decode_step(params, token, cache, pos):
        return T.decode_step(params, cfg, token, cache, pos, ctx=ctx)
    return decode_step
