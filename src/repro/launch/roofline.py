"""Roofline model for the TPU v5e target (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh), all in seconds-per-step per chip:

  compute_s    = dot_flops_per_device / PEAK_FLOPS
                 (dot_flops from the scan-aware HLO analysis — matmul FLOPs
                 dominate; elementwise ops are folded into the memory term)
  memory_s     = hbm_bytes_per_device / HBM_BW
                 (analytic traffic model below; cost_analysis' byte counter
                 shares the while-body undercount, so we model it)
  collective_s = collective_bytes_per_device / LINK_BW
                 (scan-aware HLO collective bytes; all-reduce counted 2x)

MODEL_FLOPS (6*N_active*D for training, 2*N_active*tokens for inference)
gives the useful-compute ratio that catches remat/redundancy waste.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.configs import INPUT_SHAPES, ModelConfig, active_param_count, param_count

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / ICI link (1-link conservative)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Global useful FLOPs per step (the 6ND / 2ND convention)."""
    shp = INPUT_SHAPES[shape_name]
    n_active = active_param_count(cfg)
    if shp.kind == "train":
        return 6.0 * n_active * shp.global_batch * shp.seq_len
    if shp.kind == "prefill":
        return 2.0 * n_active * shp.global_batch * shp.seq_len
    return 2.0 * n_active * shp.global_batch          # decode: one token


def _bytes_per_param_train() -> float:
    # bf16 param r+w (4) + fp32 master r+w (8) + fp32 m r+w (8)
    # + fp32 v r+w (8) + bf16 grad w+r (4)
    return 32.0


def hbm_bytes(cfg: ModelConfig, shape_name: str, n_chips: int) -> float:
    """Per-device HBM traffic per step (analytic, documented model)."""
    shp = INPUT_SHAPES[shape_name]
    n_params = param_count(cfg)
    B, S = shp.global_batch, shp.seq_len
    D, L = cfg.d_model, cfg.n_layers
    p_local = n_params / n_chips                       # fully sharded
    b_local = max(B / max(n_chips // 16, 1), 1)        # data axes extent
    act_unit = b_local * S * D * 2.0                   # one bf16 activation
    if shp.kind == "train":
        # fwd+bwd touch ~8 activation tensors per layer; remat re-runs fwd
        act = 12.0 * L * act_unit
        return p_local * _bytes_per_param_train() + act
    if shp.kind == "prefill":
        act = 6.0 * L * act_unit
        cache_w = _cache_bytes(cfg, B, S) / n_chips
        return p_local * 2.0 + act + cache_w
    # decode: weights once + the whole cache read per token
    cache_r = _cache_bytes(cfg, B, S) / n_chips
    return p_local * 2.0 + cache_r + 4.0 * L * (b_local * D * 2.0)


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind in ("global", "crossdec"):
            if cfg.mla is not None:
                total += B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
            else:
                total += 2 * B * S * cfg.n_kv_heads * hd * 2
            if kind == "crossdec":
                total += 2 * B * cfg.encoder.n_ctx * cfg.n_heads * hd * 2
        elif kind == "local":
            total += 2 * B * min(cfg.window, S) * cfg.n_kv_heads * hd * 2
        elif kind == "rglru":
            total += B * cfg.d_rnn * 4
        elif kind == "mlstm":
            H = cfg.ssm.n_heads
            dm = 2 * cfg.d_model
            total += B * H * (dm // H) ** 2 * 4
        elif kind == "slstm":
            total += 4 * B * cfg.d_model * 4
    return total


def terms(cfg: ModelConfig, shape_name: str, hlo_stats: Dict[str, float],
          n_chips: int) -> Dict[str, Any]:
    comp = hlo_stats.get("dot_flops", 0.0) / PEAK_FLOPS
    mem = hbm_bytes(cfg, shape_name, n_chips) / HBM_BW
    coll = hlo_stats.get("coll_total", 0.0) / LINK_BW
    mf = model_flops(cfg, shape_name)
    dev_flops = hlo_stats.get("dot_flops", 0.0)
    out = {
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "model_flops_global": mf,
        "useful_ratio": (mf / n_chips) / dev_flops if dev_flops else 0.0,
        "dominant": max((("compute", comp), ("memory", mem),
                         ("collective", coll)), key=lambda kv: kv[1])[0],
        "step_s_lower_bound": max(comp, mem, coll),
    }
    return out
