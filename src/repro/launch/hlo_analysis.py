"""Scan-aware analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE (validated in
tests/test_hlo_analysis.py), which under-reports every scanned layer stack
by its trip count. This module re-derives the two roofline numerators that
must be trip-count-exact from the HLO text itself:

  * dot/convolution FLOPs  (the compute term's numerator)
  * collective bytes       (all-reduce / all-gather / reduce-scatter /
                            all-to-all / collective-permute)

Method: split the module into computations, build the call graph
(while/fusion/call/conditional/to_apply edges), extract each while loop's
trip count from its condition (max integer constant), and accumulate
direct costs times the product of enclosing trip counts. Shapes in the
partitioned module are per-device, so all results are per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*\(?\s*(\w+)\[([\d,]*)\]")
_DOT = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?\bdot\(([^)]*)\)"
    r".*?lhs_contracting_dims=\{([\d,]*)\}")
_CONV = re.compile(r"=\s*(\w+)\[([\d,]*)\][^=]*?\bconvolution\(")
_COLL = re.compile(
    r"=\s*\(?\s*(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_CALL = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_CONST = re.compile(r"constant\((\d+)\)")
_WHILE = re.compile(r"\bwhile\(.*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP = re.compile(r"known_trip_count\D+(\d+)")

_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _nelems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


@dataclass
class CompCost:
    dot_flops: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    # (op, dtype, dims, bytes) per collective — for the detail profile
    coll_ops: List[Tuple[str, str, str, float]] = field(default_factory=list)
    # (callee, multiplier) edges; while bodies get their trip count
    calls: List[Tuple[str, float]] = field(default_factory=list)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        s = line.strip()
        m = _COMP_HDR.match(s)
        if m and s.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if s.startswith("ENTRY"):
                entry = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    comps["__entry__"] = comps.get(entry, [])
    if entry:
        comps["__entry_name__"] = [entry]  # type: ignore
    return comps


def _dot_flops_line(line: str, symtab: Dict[str, List[int]]) -> float:
    m = _DOT.search(line)
    if not m:
        return 0.0
    out_elems = _nelems(m.group(2))
    # contracted size: resolve the lhs operand's shape via the symbol table
    operands = [o.strip().lstrip("%") for o in m.group(3).split(",")]
    inline = _SHAPE.findall(m.group(3))
    if inline:  # dialects with typed operands
        lhs_dims = [int(x) for x in inline[0][1].split(",") if x.strip()]
    else:
        lhs_dims = symtab.get(operands[0], [])
    cdims = [int(x) for x in m.group(4).split(",") if x.strip()]
    csize = 1
    for c in cdims:
        if c < len(lhs_dims):
            csize *= lhs_dims[c]
    return 2.0 * out_elems * csize


def analyze(text: str) -> Dict[str, float]:
    comps = _split_computations(text)
    entry_name = comps.pop("__entry_name__", [None])[0]  # type: ignore
    comps.pop("__entry__", None)

    costs: Dict[str, CompCost] = {}
    trip: Dict[str, float] = {}

    for name, lines in comps.items():
        c = CompCost()
        symtab: Dict[str, List[int]] = {}
        for ln in lines:
            dm = _DEF.match(ln)
            if dm:
                symtab[dm.group(1)] = [int(x) for x in dm.group(3).split(",")
                                       if x.strip()]
        for ln in lines:
            c.dot_flops += _dot_flops_line(ln, symtab)
            if "convolution(" in ln:
                pass  # VGG paths are not dry-run targets; ignored
            mc = _COLL.search(ln)
            if mc and "-done(" not in ln:
                dt, dims, op = mc.group(1), mc.group(2), mc.group(3)
                b = _nelems(dims) * _DTYPE_BYTES.get(dt, 4) * _COLL_FACTOR[op]
                c.coll_bytes[op] = c.coll_bytes.get(op, 0.0) + b
                c.coll_ops.append((op, dt, dims, b))
            mw = _WHILE.search(ln)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                mt = _TRIP.search(ln)
                if mt:
                    tc = float(mt.group(1))
                else:  # fall back: max integer constant in the condition
                    tc = 1.0
                    for cl in comps.get(cond, []):
                        for k in _CONST.findall(cl):
                            tc = max(tc, float(k))
                trip[body] = tc
                c.calls.append((body, tc))
                continue
            for m in _CALL.finditer(ln):
                if m.group(1):
                    c.calls.append((m.group(1), 1.0))
                elif m.group(2):
                    # conditional: take the max-cost branch (approximated
                    # by summing — branches in our models are tiny)
                    for b in m.group(2).split(","):
                        c.calls.append((b.strip().lstrip("%"), 1.0))
        costs[name] = c

    memo: Dict[str, Tuple[float, Dict[str, float]]] = {}

    def total(name: str, stack=()) -> Tuple[float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in costs or name in stack:
            return 0.0, {}
        c = costs[name]
        f = c.dot_flops
        coll = dict(c.coll_bytes)
        for callee, mult in c.calls:
            cf, ccoll = total(callee, stack + (name,))
            f += cf * mult
            for k, v in ccoll.items():
                coll[k] = coll.get(k, 0.0) + v * mult
        memo[name] = (f, coll)
        return memo[name]

    if entry_name is None:
        # fall back: the computation with the most lines
        entry_name = max(comps, key=lambda k: len(comps[k]))
    f, coll = total(entry_name)
    out = {"dot_flops": f, "coll_total": sum(coll.values())}
    for k, v in coll.items():
        out[f"coll_{k}"] = v
    return out


def collective_profile(text: str, top: int = 20) -> List[Dict]:
    """Per-op collective profile with effective trip multipliers — the
    'where do the collective bytes come from' view for §Perf."""
    comps = _split_computations(text)
    entry_name = comps.pop("__entry_name__", [None])[0]  # type: ignore
    comps.pop("__entry__", None)

    costs: Dict[str, CompCost] = {}
    for name, lines in comps.items():
        c = CompCost()
        for ln in lines:
            mc = _COLL.search(ln)
            if mc and "-done(" not in ln:
                op, dt, dims = mc.group(3), mc.group(1), mc.group(2)
                b = _nelems(dims) * _DTYPE_BYTES.get(dt, 4) * _COLL_FACTOR[op]
                c.coll_ops.append((op, dt, dims, b))
            mw = _WHILE.search(ln)
            if mw:
                mt = _TRIP.search(ln)
                tc = float(mt.group(1)) if mt else 1.0
                c.calls.append((mw.group(2), tc))
                continue
            for m in _CALL.finditer(ln):
                if m.group(1):
                    c.calls.append((m.group(1), 1.0))
                elif m.group(2):
                    for bname in m.group(2).split(","):
                        c.calls.append((bname.strip().lstrip("%"), 1.0))
        costs[name] = c

    # multiplier of each computation = product of trip counts on the path
    mult: Dict[str, float] = {}

    def visit(name: str, m: float, stack=()):
        if name not in costs or name in stack:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, k in costs[name].calls:
            visit(callee, m * k, stack + (name,))

    if entry_name is None:
        entry_name = max(comps, key=lambda k: len(comps[k]))
    visit(entry_name, 1.0)

    rows: List[Dict] = []
    for name, c in costs.items():
        m = mult.get(name, 0.0)
        if not m:
            continue
        for op, dt, dims, b in c.coll_ops:
            rows.append({"op": op, "dtype": dt, "shape": dims,
                         "bytes_each": b, "mult": m, "total": b * m,
                         "comp": name})
    rows.sort(key=lambda r: -r["total"])
    return rows[:top]
