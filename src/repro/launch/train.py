"""Training launcher: end-to-end LM training of any registered arch.

Runs at any scale: on this CPU container use a reduced config
(``--reduced``); on a real pod the same entry point drives the production
mesh (``--mesh single|multi``).

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import get_config, reduced
from repro.data import LMPipeline
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import adamw, cosine_with_warmup
from repro.sharding.ctx import CPU_CTX


def run(arch: str, *, use_reduced: bool = True, steps: int = 100,
        batch: int = 8, seq: int = 128, lr: float = 3e-4,
        log_every: int = 10, ckpt: str | None = None, seed: int = 0,
        d_model: int = 256, n_units: int = 1):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg, d_model=d_model, n_units=n_units)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt = adamw(cosine_with_warmup(lr, steps // 10, steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, ctx=CPU_CTX, loss_chunk=0))
    pipe = LMPipeline(cfg.vocab_size, batch, seq, seed=seed)

    aux = None
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        aux = jnp.zeros((batch, cfg.frontend.n_prefix, cfg.d_model), cfg.dtype)
    if cfg.encoder is not None:
        aux = jnp.zeros((batch, cfg.encoder.n_ctx, cfg.d_model), cfg.dtype)

    losses = []
    t0 = time.time()
    for step, host_batch in zip(range(steps), pipe):
        b = {"tokens": jnp.asarray(host_batch["tokens"]),
             "labels": jnp.asarray(host_batch["labels"])}
        if aux is not None:
            b["aux"] = aux
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jnp.int32(step), b)
        losses.append(float(metrics["loss"]))
        if (step + 1) % log_every == 0:
            dt = (time.time() - t0) / (step + 1)
            print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                  f"({dt*1e3:.0f} ms/step)")
    if ckpt:
        save_pytree(ckpt, params, extra={"arch": cfg.name, "steps": steps})
        print(f"saved {ckpt}")
    return {"losses": losses, "params": params, "cfg": cfg}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    res = run(args.arch, use_reduced=args.reduced, steps=args.steps,
              batch=args.batch, seq=args.seq, lr=args.lr, ckpt=args.ckpt,
              d_model=args.d_model)
    l0 = np.mean(res["losses"][:10])
    l1 = np.mean(res["losses"][-10:])
    print(f"loss {l0:.3f} -> {l1:.3f} ({'improved' if l1 < l0 else 'FLAT'})")


if __name__ == "__main__":
    main()
