"""ShapeDtypeStruct stand-ins for every model input (no allocation) and
the matching sharding trees — consumed by the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ModelConfig
from repro.models import transformer as T
from repro.sharding import rules


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """SDS tree for the data part of a step's inputs."""
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    adt = cfg.dtype
    if shp.kind in ("train", "prefill"):
        n_text = S
        out: Dict[str, Any] = {}
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            n_text = S - cfg.frontend.n_prefix
            out["aux"] = _sds((B, cfg.frontend.n_prefix, cfg.d_model), adt)
        if cfg.encoder is not None:
            out["aux"] = _sds((B, cfg.encoder.n_ctx, cfg.d_model), adt)
        out["tokens"] = _sds((B, n_text), jnp.int32)
        if shp.kind == "train":
            out["labels"] = _sds((B, n_text), jnp.int32)
        return out
    # decode: one token vs an S-entry cache
    return {"token": _sds((B, 1), jnp.int32),
            "cache": jax.eval_shape(lambda: T.init_cache(cfg, B, S)),
            "pos": _sds((), jnp.int32)}


def param_sds(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def opt_sds(cfg: ModelConfig, optimizer, params_sds):
    return jax.eval_shape(optimizer.init, params_sds)


def data_shardings(cfg: ModelConfig, shape_name: str, mesh,
                   batch_sds) -> Dict[str, Any]:
    """NamedSharding tree matching ``batch_specs``."""
    da = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    shp = INPUT_SHAPES[shape_name]
    extent = 1
    for a in da:
        extent *= mesh.shape[a]
    shardable = shp.global_batch % extent == 0 and shp.global_batch >= extent
    dp = da if shardable else None

    def shard_batch_leaf(leaf):
        spec = [None] * len(leaf.shape)
        if dp and leaf.shape[0] % extent == 0:
            spec[0] = dp if len(dp) > 1 else dp[0]
        return NamedSharding(mesh, P(*spec))

    out = {}
    for k, v in batch_sds.items():
        if k == "cache":
            specs = rules.cache_specs(v, mesh, da, batch_shardable=shardable)
            out[k] = rules.named(mesh, specs)
        elif k == "pos":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = jax.tree.map(shard_batch_leaf, v)
    return out


def param_shardings(cfg: ModelConfig, mesh, params_sds, *,
                    embed_tp: bool = False):
    da = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return rules.named(mesh, rules.param_specs(params_sds, mesh, da,
                                               embed_tp=embed_tp))
