import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production mesh, and extract the roofline
terms from the compiled artifact.

MUST be imported before anything that initializes jax (the device count is
locked at first backend init) — hence the XLA_FLAGS lines above everything.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.launch import hlo_analysis, roofline as RL  # noqa: E402
from repro.launch.mesh import data_axes, make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    make_decode_step, make_prefill_step, make_train_step)
from repro.optim import adamw  # noqa: E402
from repro.sharding.ctx import ShardCtx  # noqa: E402


def build_step(cfg, shape_name: str, ctx: ShardCtx):
    kind = INPUT_SHAPES[shape_name].kind
    if kind == "train":
        opt = adamw(1e-4)
        return make_train_step(cfg, opt, ctx=ctx, loss_chunk=512), opt
    if kind == "prefill":
        return make_prefill_step(cfg, ctx=ctx,
                                 cache_len=INPUT_SHAPES[shape_name].seq_len), None
    return make_decode_step(cfg, ctx=ctx), None


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              ctx_kw: Optional[Dict[str, Any]] = None,
              compile_: bool = True, profile: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch).with_dtype("bfloat16")
    shp = INPUT_SHAPES[shape_name]
    if shp.name == "long_500k" and not cfg.sub_quadratic:
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "reason": "full-attention architecture (DESIGN.md §6)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    da = data_axes(mesh)
    ctx = ShardCtx(mesh=mesh, data_axes=da, model_axis="model", remat=True,
                   **(ctx_kw or {}))
    bspec = SP.batch_specs(cfg, shape_name)
    bshard = SP.data_shardings(cfg, shape_name, mesh, bspec)
    psds = SP.param_sds(cfg)
    pshard = SP.param_shardings(cfg, mesh, psds, embed_tp=ctx.embed_tp)
    step, opt = build_step(cfg, shape_name, ctx)
    t0 = time.time()
    with mesh:
        if shp.kind == "train":
            osds = SP.opt_sds(cfg, opt, psds)
            from repro.sharding import rules
            oshard = rules.named(mesh, rules.param_specs(
                osds, mesh, da, embed_tp=ctx.embed_tp))
            jfn = jax.jit(step,
                          in_shardings=(pshard, oshard,
                                        NamedSharding(mesh, P()), bshard),
                          out_shardings=(pshard, oshard,
                                         NamedSharding(mesh, P())))
            lowered = jfn.lower(psds, osds,
                                jax.ShapeDtypeStruct((), jnp.int32), bspec)
        elif shp.kind == "prefill":
            jfn = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jfn.lower(psds, bspec)
        else:
            jfn = jax.jit(
                step,
                in_shardings=(pshard, bshard["token"], bshard["cache"],
                              bshard["pos"]),
                out_shardings=(NamedSharding(mesh, P()), bshard["cache"]))
            lowered = jfn.lower(psds, bspec["token"], bspec["cache"],
                                bspec["pos"])
        t_lower = time.time() - t0
        res: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                               "mesh": "2x16x16" if multi_pod else "16x16",
                               "status": "LOWERED", "t_lower_s": t_lower}
        if not compile_:
            return res
        t1 = time.time()
        compiled = lowered.compile()
        res["t_compile_s"] = time.time() - t1
        res["status"] = "OK"
        ca = compiled.cost_analysis() or {}
        res["raw_flops"] = float(ca.get("flops", -1.0))
        res["raw_bytes"] = float(ca.get("bytes accessed", -1.0))
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes"):
                try:
                    res[k] = int(getattr(ma, k))
                except Exception:
                    pass
        text = compiled.as_text()
        res["hlo"] = hlo_analysis.analyze(text)
        if profile:
            res["profile"] = hlo_analysis.collective_profile(text, top=12)
        return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--ctx", default="",
                    help="ShardCtx overrides, e.g. "
                         "causal_skip=1,embed_tp=1,remat_policy=dots")
    ap.add_argument("--profile", action="store_true",
                    help="emit the top collective ops (bytes x trips)")
    args = ap.parse_args()

    ctx_kw: Dict[str, Any] = {}
    for kv in filter(None, args.ctx.split(",")):
        k, _, v = kv.partition("=")
        if v in ("0", "1", "true", "false", "True", "False"):
            ctx_kw[k] = v in ("1", "true", "True")
        elif v.isdigit():
            ctx_kw[k] = int(v)
        else:
            ctx_kw[k] = v

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape
        pairs.append((args.arch, args.shape))

    results = []
    for a, s in pairs:
        try:
            r = lower_one(a, s, multi_pod=args.multi_pod, ctx_kw=ctx_kw,
                          compile_=not args.no_compile, profile=args.profile)
        except Exception as e:  # a failure here is a bug in the system
            r = {"arch": a, "shape": s, "status": "FAIL",
                 "error": f"{type(e).__name__}: {e}"}
        if r["status"] == "OK":
            cfg = get_config(a)
            r["roofline"] = RL.terms(cfg, s, r["hlo"],
                                     512 if args.multi_pod else 256)
        print(json.dumps(r, default=float))
        sys.stdout.flush()
        results.append(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=float)
    n_bad = sum(1 for r in results if r["status"] == "FAIL")
    print(f"# done: {len(results)} pairs, {n_bad} failures", file=sys.stderr)
    sys.exit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
