"""Production meshes (functions, so importing never touches device state).

Single pod: 256 x TPU v5e as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the batch
(and FSDP) dimension spans ("pod", "data") — DCN-friendly: only
data-parallel gradient reductions cross the pod boundary.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """Whatever devices exist locally, as a 1D (data) mesh — used by the
    smoke-scale launchers."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
