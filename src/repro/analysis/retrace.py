"""Retrace detector — count XLA compilations across a region of code.

``fl/engine.py`` jits one training step per participating-subset SIZE
(``UnifiedEngine._steps``); the known hazard is anything that silently
multiplies that cache — weak-typed scalars, re-built closures, unhashable
statics — turning "compile once, run for hours" into a compile per
round. This context manager counts jit cache MISSES via
``jax.monitoring``: jax emits a ``backend_compile`` duration event on
every XLA compilation and nothing on a cache hit, so

    with RetraceDetector() as det:
        fed.run(rounds=5)
    assert det.compiles <= expected

is a direct, dependency-free probe. ``checkpoint()`` snapshots the count
mid-region (the retrace regression test snapshots after round 1 and
asserts the final count equals the snapshot).

jax.monitoring has global listener registration only (no per-listener
removal short of ``clear_event_listeners``, which would clobber other
subscribers), so ONE module-level listener is registered lazily on first
use and fans out to whichever detectors are currently active; inactive
detectors cost a truth test per compile event.

Not part of the default ``python -m repro.analysis`` run — detecting
retraces requires actually executing the federation; the tier-1 test
``tests/test_retrace.py`` is its consumer.
"""
from __future__ import annotations

from typing import List, Optional

from jax import monitoring

# any backend_compile duration event == one jit cache miss; match on the
# stem so jax-version renames (backend_compile vs backend_compile_duration)
# keep matching
_COMPILE_EVENT_STEM = "/jax/core/compile/backend_compile"

_ACTIVE: List["RetraceDetector"] = []
_REGISTERED = False


def _on_duration(event: str, duration: float, **kwargs) -> None:
    if not event.startswith(_COMPILE_EVENT_STEM):
        return
    for det in _ACTIVE:
        det._record(event)


def _ensure_listener() -> None:
    global _REGISTERED
    if not _REGISTERED:
        monitoring.register_event_duration_secs_listener(_on_duration)
        _REGISTERED = True


class RetraceDetector:
    """Context manager counting XLA compilations while active.

    ``compiles``   — count since ``__enter__`` (monotone).
    ``checkpoint()`` — stash the current count and return it.
    ``since_checkpoint`` — compiles since the last checkpoint (or entry).
    ``events``     — the raw event names, for diagnostics.

    Nesting is fine: each active detector counts independently.
    """

    def __init__(self) -> None:
        self.compiles = 0
        self.events: List[str] = []
        self._mark = 0
        self._entered = False

    # called from the module listener
    def _record(self, event: str) -> None:
        self.compiles += 1
        self.events.append(event)

    def checkpoint(self) -> int:
        self._mark = self.compiles
        return self._mark

    @property
    def since_checkpoint(self) -> int:
        return self.compiles - self._mark

    def __enter__(self) -> "RetraceDetector":
        if self._entered:
            raise RuntimeError("RetraceDetector is not reentrant; "
                               "create a new instance")
        _ensure_listener()
        self._entered = True
        self.compiles = 0
        self._mark = 0
        self.events.clear()
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        _ACTIVE.remove(self)
        self._entered = False
        return None
