"""fedlint — AST rules for JAX hazards the ruff gate cannot express.

Rules (suppress inline with ``# fedlint: ignore[RULE]`` on the flagged
line, reason recommended after the bracket):

  FDL001  PRNG key reuse: the same key name is passed as an argument to
          two or more call sites inside one function body without an
          intervening ``jax.random.split`` / reassignment. Reusing a key
          silently correlates "independent" randomness — the classic
          federated-sampling bug.
  FDL002  Hazardous jit signature: a function decorated with ``jax.jit``
          / ``jax.pmap`` (or wrapped via ``partial(jax.jit, ...)``) has a
          mutable default argument (list/dict/set) or a default on a
          ``static_argnames`` parameter that is unhashable. Mutable
          defaults leak state across traces; unhashable statics fail at
          call time, but only on the first cache miss.
  FDL003  Module-scope device work: ``jnp.*`` array construction or
          ``jax.device_put`` executed at import time. Import of a leaf
          module then allocates on whatever device jax initializes
          first — breaks CPU-only CI and multi-process setups. (Module
          scope means outside any def/class; annotation-only or
          ``TYPE_CHECKING`` uses are fine.)
  FDL004  Python branching on traced values: ``if``/``while`` whose test
          reads a parameter of a jit-compiled function (or compares its
          ``.shape`` elements) inside that function. Under trace this
          either raises ConcretizationError or — worse — silently bakes
          one branch. ``is``/``is not None`` tests (static pytree
          structure) and parameters named in ``static_argnames`` /
          ``static_argnums`` are exempt.

The checker is intentionally first-order: it inspects one file at a
time, resolves only literal ``jax.jit`` / ``jit`` / ``pjit`` / ``pmap``
spellings, and prefers false negatives over noisy false positives —
every rule fires only on patterns that are locally provable.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis import Finding

RULES = ("FDL001", "FDL002", "FDL003", "FDL004")

_IGNORE_RE = re.compile(r"#\s*fedlint:\s*ignore\[([A-Z0-9,\s]+)\]")

DEFAULT_ROOTS = ("src", "tools", "examples", "benchmarks", "tests")


# --------------------------------------------------------------- utilities
def _suppressions(source: str) -> Dict[int, Set[str]]:
    """line number -> set of rule ids suppressed on that line."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _dotted(node: ast.AST) -> str:
    """'jax.random.split' for an Attribute/Name chain, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


_JIT_NAMES = {"jax.jit", "jit", "jax.pmap", "pmap", "pjit", "jax.pjit",
              "functools.partial", "partial"}


def _jit_decoration(dec: ast.AST) -> Optional[ast.Call]:
    """Return the decorating Call if ``dec`` applies jit/pmap (possibly
    through ``partial(jax.jit, ...)``), else None. Bare ``@jax.jit``
    (no call) returns a synthetic empty Call for uniform handling."""
    if isinstance(dec, ast.Call):
        name = _dotted(dec.func)
        if name in ("functools.partial", "partial"):
            if dec.args and _dotted(dec.args[0]) in _JIT_NAMES:
                return dec
            return None
        if name in _JIT_NAMES - {"functools.partial", "partial"}:
            return dec
        return None
    if _dotted(dec) in _JIT_NAMES - {"functools.partial", "partial"}:
        return ast.Call(func=dec, args=[], keywords=[])
    return None


def _static_params(call: ast.Call, fn: ast.FunctionDef) -> Set[str]:
    """Parameter names marked static via static_argnames/static_argnums
    literals on the jit call."""
    names: Set[str] = set()
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    if 0 <= n.value < len(params):
                        names.add(params[n.value])
    return names


def _iter_functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ------------------------------------------------------------------ FDL001
# singular only: plural 'keys' is this repo's path-tuple idiom, and split
# products are consumed via subscripts (keys[0]) which we don't track
_KEY_HINT = re.compile(r"(^|_)(key|rng|prng)($|_|\d)")

_STMT_BODIES = ("body", "orelse", "finalbody")


def _expr_children(st: ast.stmt):
    """The statement's OWN expression parts — no nested statement bodies
    (those are visited separately, branch-aware) and no nested defs
    (their free-variable uses are counted when that def is checked)."""
    for field, value in ast.iter_fields(st):
        if field in _STMT_BODIES + ("handlers",):
            continue
        if isinstance(value, ast.AST):
            yield value
        elif isinstance(value, list):
            for v in value:
                if isinstance(v, ast.AST):
                    yield v


def _check_key_reuse(fn: ast.FunctionDef) -> List[Tuple[int, str, str]]:
    """Flag a key-named variable consumed as a call argument twice on one
    control-flow path without an intervening split/fold_in/rebinding.
    Exclusive ``if``/``else`` branches merge by max (one path executes);
    a loop body counts double (every iteration consumes)."""
    out: List[Tuple[int, str, str]] = []
    flagged: Set[str] = set()

    def consume(st: ast.stmt, uses: Dict[str, int], mult: int,
                nonkeys: Set[str]) -> None:
        for expr in _expr_children(st):
            for call in (n for n in ast.walk(expr)
                         if isinstance(n, ast.Call)):
                callee = _dotted(call.func)
                is_split = callee.endswith("split") or \
                    callee.endswith("fold_in")
                for arg in list(call.args) + [k.value for k in
                                              call.keywords]:
                    if not isinstance(arg, ast.Name) or \
                            not _KEY_HINT.search(arg.id) or \
                            arg.id in nonkeys:
                        continue
                    name = arg.id
                    if is_split:
                        uses.pop(name, None)  # split(key) retires the key
                        continue
                    count = uses.get(name, 0) + mult
                    if count > 1 and name not in flagged:
                        flagged.add(name)
                        out.append((
                            call.lineno, "FDL001",
                            f"PRNG key '{name}' consumed more than once "
                            "without jax.random.split — randomness is "
                            "correlated across the consumers"))
                    uses[name] = count

    def rebind(st: ast.stmt, uses: Dict[str, int],
               nonkeys: Set[str]) -> None:
        targets: List[ast.AST] = []
        value = None
        if isinstance(st, ast.Assign):
            targets, value = list(st.targets), st.value
        elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
            targets, value = [st.target], st.value
        elif isinstance(st, ast.For):
            targets = [st.target]
        # a hint-named variable visibly bound to a NON-random source
        # (key_pos = jnp.arange(S)) is not a PRNG key — stop tracking it
        # until it is rebound to one
        random_src = True
        if isinstance(value, ast.Call):
            callee = _dotted(value.func)
            random_src = ("random" in callee or callee.endswith("split")
                          or callee.endswith("fold_in")
                          or callee.endswith("PRNGKey") or callee == "")
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name) and _KEY_HINT.search(n.id):
                    uses.pop(n.id, None)
                    if random_src:
                        nonkeys.discard(n.id)
                    else:
                        nonkeys.add(n.id)

    nonkeys: Set[str] = set()

    def visit(stmts: Iterable[ast.stmt], uses: Dict[str, int],
              mult: int) -> bool:
        """Returns True when this statement list terminates the path
        (return/raise/break/continue) — an early-returning `if` branch
        must not add its uses to the fall-through path."""
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue  # separate scope, checked on its own
            consume(st, uses, mult, nonkeys)
            rebind(st, uses, nonkeys)
            if isinstance(st, (ast.Return, ast.Raise, ast.Break,
                               ast.Continue)):
                return True
            if isinstance(st, ast.If):
                live, term = [], []
                for field in ("body", "orelse"):
                    u = dict(uses)
                    (term if visit(getattr(st, field), u, mult)
                     else live).append(u)
                if not live:
                    return True  # every branch leaves this path
                for name in {k for u in live for k in u}:
                    uses[name] = max(u.get(name, 0) for u in live)
            elif isinstance(st, (ast.For, ast.While)):
                visit(st.body, uses, mult * 2)
                visit(st.orelse, uses, mult)
            elif isinstance(st, ast.Try):
                visit(st.body, uses, mult)
                for h in st.handlers:
                    visit(h.body, dict(uses), mult)
                visit(st.orelse, uses, mult)
                visit(st.finalbody, uses, mult)
            else:
                for field in _STMT_BODIES:
                    sub = getattr(st, field, None)
                    if sub:
                        visit(sub, uses, mult)
        return False

    visit(fn.body, {}, 1)
    return out


# ------------------------------------------------------------------ FDL002
_MUTABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
            ast.SetComp)


def _check_jit_signature(fn: ast.FunctionDef,
                         call: ast.Call) -> List[Tuple[int, str, str]]:
    out: List[Tuple[int, str, str]] = []
    statics = _static_params(call, fn)
    args = fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
    # defaults align with the TAIL of the positional params
    pos = fn.args.posonlyargs + fn.args.args
    padded = [None] * (len(pos) - len(fn.args.defaults)) + \
        list(fn.args.defaults) + list(fn.args.kw_defaults)
    for a, d in zip(args, padded):
        if d is None or not isinstance(d, _MUTABLE):
            continue
        if a.arg in statics:
            out.append((
                fn.lineno, "FDL002",
                f"static arg '{a.arg}' of jitted '{fn.name}' has an "
                "unhashable default — the first cache miss raises "
                "TypeError"))
        else:
            out.append((
                fn.lineno, "FDL002",
                f"jit-decorated '{fn.name}' has mutable default for "
                f"'{a.arg}' — state leaks across traces"))
    return out


# ------------------------------------------------------------------ FDL003
def _check_import_time_device(tree: ast.Module
                              ) -> List[Tuple[int, str, str]]:
    """jnp.* / jax.device_put calls executed at module scope."""
    out: List[Tuple[int, str, str]] = []
    for st in tree.body:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef, ast.Import, ast.ImportFrom)):
            continue
        if isinstance(st, ast.If):
            # `if TYPE_CHECKING:` / __main__ guards are not import work
            continue
        for call in (n for n in ast.walk(st) if isinstance(n, ast.Call)):
            name = _dotted(call.func)
            if name.startswith("jnp.") or name.startswith("jax.numpy.") \
                    or name in ("jax.device_put", "jax.random.PRNGKey"):
                out.append((
                    call.lineno, "FDL003",
                    f"'{name}' runs at import time — allocates on the "
                    "default device before the program chose one"))
    return out


# ------------------------------------------------------------------ FDL004
def _check_traced_branching(fn: ast.FunctionDef, call: ast.Call
                            ) -> List[Tuple[int, str, str]]:
    """Python `if`/`while` on a traced parameter inside a jitted fn."""
    statics = _static_params(call, fn)
    params = {a.arg for a in fn.args.posonlyargs + fn.args.args +
              fn.args.kwonlyargs} - statics - {"self"}
    out: List[Tuple[int, str, str]] = []

    def is_none_test(test: ast.AST) -> bool:
        return isinstance(test, ast.Compare) and \
            all(isinstance(op, (ast.Is, ast.IsNot))
                for op in test.ops)

    for node in ast.walk(fn):
        if not isinstance(node, (ast.If, ast.While)):
            continue
        test = node.test
        if is_none_test(test):
            continue
        for n in ast.walk(test):
            if isinstance(n, ast.Name) and n.id in params:
                # `.shape`/`.ndim` reads are static; a bare traced value
                # in a bool context is the hazard
                parent_attr = any(
                    isinstance(p, ast.Attribute) and
                    p.attr in ("shape", "ndim", "dtype", "size")
                    for p in ast.walk(test)
                    if isinstance(p, ast.Attribute) and
                    isinstance(p.value, ast.Name) and p.value.id == n.id)
                if parent_attr:
                    continue
                out.append((
                    node.lineno, "FDL004",
                    f"Python branch on traced parameter '{n.id}' inside "
                    f"jitted '{fn.name}' — use lax.cond/lax.select or "
                    "mark it static"))
                break
    return out


# ---------------------------------------------------------------- driver
def lint_source(source: str, filename: str) -> List[Finding]:
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        return [Finding("lint", "parse", filename, e.lineno or 0,
                        f"syntax error: {e.msg}")]
    sup = _suppressions(source)
    raw: List[Tuple[int, str, str]] = []
    raw += _check_import_time_device(tree)
    for fn in _iter_functions(tree):
        raw += _check_key_reuse(fn)
        for dec in fn.decorator_list:
            call = _jit_decoration(dec)
            if call is None:
                continue
            raw += _check_jit_signature(fn, call)
            raw += _check_traced_branching(fn, call)
    out = []
    for line, rule, msg in sorted(raw):
        if rule in sup.get(line, ()):
            continue
        out.append(Finding("lint", rule, filename, line, msg))
    return out


def lint_file(path: Path) -> List[Finding]:
    return lint_source(path.read_text(), str(path))


def iter_py_files(roots: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for root in roots:
        p = Path(root)
        if p.is_file():
            files.append(p)
        else:
            files.extend(sorted(p.rglob("*.py")))
    return files


def lint_roots(roots: Optional[Sequence[str]] = None
               ) -> Tuple[List[Finding], int]:
    """Lint every ``*.py`` under the roots (default: ``src/``); returns
    (findings, number of files checked)."""
    files = iter_py_files(roots or DEFAULT_ROOTS)
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_file(f))
    return findings, len(files)
