"""Static contract checker: the FedADP algebra, proven per architecture
under abstract evaluation.

For every architecture in ``models/registry.py`` (reduced to smoke
dimensions, as a heterogeneous variant cohort under
``TransformerFamily``) and for the paper's VGG cohort (scaled, under
``VGGFamily``), verify:

  * ``up``/``down``/``up(down(·))`` preserve tree structure, shapes and
    dtypes — under ``jax.eval_shape``, both narrow modes, no FLOPs;
  * ``segment_spec`` covers EXACTLY the width-differing axes of every
    client-owned union leaf (no missing axis, no spurious one), and each
    ``AxisSeg``'s ids/counts are consistent with the client extent;
  * ``coverage_mask`` invariants: masks are 0/1, loose ⊇ strict, the
    loose reading equals ``loosen(strict, filler)`` (i.e. parameter
    landing sites and filler constants are disjoint), computed on
    constant pushes of the tiny reduced configs — no model evaluation;
  * ``multiplicity`` matches the segment metadata: counts are integers
    ≥ 1, equal to the per-leaf product of segment sizes, 1 off the
    spec's leaves, and > 1 only on strictly-covered coordinates;
  * ``PlaneSpec`` pack → unpack → pack is the identity layout (abstract
    for shapes/dtypes, exact at value level on all-f32 cohorts) and the
    ``to_manifest``/``from_manifest`` serialization round-trips.

Nothing here runs a training step or a forward pass; the whole registry
matrix completes in seconds (acceptance: < 60 s).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import Finding
from repro.core import plane, tfamily
from repro.core.aggregation import (coverage_and_filler, coverage_mask,
                                    global_shapes, loosen, multiplicity)
from repro.core.family import TransformerFamily, VGGFamily
from repro.core.segments import path_keys
from repro.configs import get_config, reduced
from repro.configs.vgg_family import PAPER_COHORT, scaled, vgg
from repro.models.registry import arch_ids

SEED = 7           # one fixed NetChange seed for the whole matrix
NARROW_MODES = ("paper", "fold")


@dataclasses.dataclass(frozen=True)
class Case:
    """One (family, cohort) cell of the contract matrix."""
    name: str                 # e.g. "transformer/glm4-9b", "vgg/paper"
    family: Any
    client_cfgs: Tuple[Any, ...]


# ------------------------------------------------------------ enumeration
def transformer_cohort(arch: str) -> Case:
    """A depth + width heterogeneous variant cohort of one registry
    architecture, at smoke dimensions (``configs.reduced``). Prefers the
    widest heterogeneity the family declares representable (depth+FFN),
    falling back to depth-only for cohorts whose width knob lives
    outside the unified domain (MoE expert width, d_rnn —
    DESIGN.md §Arch-applicability)."""
    fam = TransformerFamily()
    base = reduced(get_config(arch), n_units=2, d_model=64)
    variant = base
    for kw in (dict(n_units=1, ffn_scale=0.5), dict(n_units=1), dict()):
        variant = tfamily.make_variant(base, **kw)
        if fam.segment_representable([variant, base]):
            break
    return Case(f"transformer/{arch}", fam, (variant, base))


def vgg_cohort() -> Case:
    """The paper's 8-architecture cohort at reduced scale (depth AND
    width heterogeneity — the '-wider' variants widen a stage-4 conv)."""
    cfgs = tuple(scaled(vgg(a), 0.125, 32) for a in PAPER_COHORT)
    return Case("vgg/paper-x0.125", VGGFamily(), cfgs)


def all_cases(*, quick: bool = False) -> List[Case]:
    archs = arch_ids()[:2] if quick else arch_ids()
    return [vgg_cohort()] + [transformer_cohort(a) for a in archs]


# ------------------------------------------------------------- primitives
def _flat_shapes(tree) -> List[Tuple[Tuple[str, ...], Tuple[int, ...], str]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_keys(p), tuple(l.shape), str(l.dtype)) for p, l in flat]


def _diff_trees(what: str, got, want, *, case: str) -> List[Finding]:
    """Structural + shape + dtype comparison of two (abstract) trees,
    findings name the first offending leaves."""
    out: List[Finding] = []
    a, b = _flat_shapes(got), _flat_shapes(want)
    paths_a = {p for p, _, _ in a}
    paths_b = {p for p, _, _ in b}
    for p in sorted(paths_b - paths_a):
        out.append(Finding("contracts", what, case, 0,
                           f"leaf '{'/'.join(p)}' missing from result"))
    for p in sorted(paths_a - paths_b):
        out.append(Finding("contracts", what, case, 0,
                           f"unexpected leaf '{'/'.join(p)}' in result"))
    want_by_path = {p: (s, d) for p, s, d in b}
    for p, s, d in a:
        if p not in want_by_path:
            continue
        ws, wd = want_by_path[p]
        if s != ws:
            out.append(Finding("contracts", what, case, 0,
                               f"leaf '{'/'.join(p)}': shape {s}, "
                               f"expected {ws}"))
        elif d != wd:
            out.append(Finding("contracts", what, case, 0,
                               f"leaf '{'/'.join(p)}': dtype {d}, "
                               f"expected {wd}"))
    return out


def _client_shapes(family, cfg):
    return jax.eval_shape(lambda k: family.init(k, cfg),
                          jax.random.PRNGKey(0))


# ----------------------------------------------------------------- checks
def check_updown(case: Case) -> List[Finding]:
    """up, down, and up(down(·)) preserve structure/shapes/dtypes —
    abstract evaluation only."""
    out: List[Finding] = []
    fam = case.family
    union = fam.union(list(case.client_cfgs))
    gshapes = global_shapes(fam, union)
    for ci, cfg in enumerate(case.client_cfgs):
        where = f"{case.name}/client{ci}"
        cshapes = _client_shapes(fam, cfg)
        up_shapes = jax.eval_shape(
            lambda p: fam.up(p, cfg, union, seed=SEED), cshapes)
        out += _diff_trees("up-shape", up_shapes, gshapes, case=where)
        for mode in NARROW_MODES:
            down_shapes = jax.eval_shape(
                lambda p: fam.down(p, union, cfg, seed=SEED, mode=mode),
                gshapes)
            out += _diff_trees(f"down-shape[{mode}]", down_shapes, cshapes,
                               case=where)
            rt = jax.eval_shape(
                lambda p: fam.up(
                    fam.down(p, union, cfg, seed=SEED, mode=mode),
                    cfg, union, seed=SEED),
                gshapes)
            out += _diff_trees(f"updown-shape[{mode}]", rt, gshapes,
                               case=where)
    return out


def _depth_axes(path: Tuple[str, ...]) -> Tuple[int, ...]:
    """Axes that encode DEPTH, not width, for a union leaf: the stacked
    unit axis of transformer ``units/*`` leaves (depth embeds there as
    extra rows, handled by zero-block padding, never by segments)."""
    return (0,) if path and path[0] == "units" else ()


def check_segment_spec(case: Case) -> List[Finding]:
    """``segment_spec`` covers exactly the width-differing axes of every
    client-owned leaf, and every AxisSeg is internally consistent."""
    out: List[Finding] = []
    fam = case.family
    union = fam.union(list(case.client_cfgs))
    gshapes = global_shapes(fam, union)
    gflat = {p: s for p, s, _ in _flat_shapes(gshapes)}
    for ci, cfg in enumerate(case.client_cfgs):
        where = f"{case.name}/client{ci}"
        spec = fam.segment_spec(cfg, union, seed=SEED)
        cflat = {p: s for p, s, _ in _flat_shapes(_client_shapes(fam, cfg))}
        # expected = width-differing axes of leaves the client owns
        expected = set()
        for p, cs in cflat.items():
            gs = gflat.get(p)
            if gs is None:
                out.append(Finding(
                    "contracts", "segment-spec", where, 0,
                    f"client leaf '{'/'.join(p)}' has no union "
                    "counterpart"))
                continue
            if len(cs) != len(gs):
                out.append(Finding(
                    "contracts", "segment-spec", where, 0,
                    f"leaf '{'/'.join(p)}': client rank {len(cs)} != "
                    f"union rank {len(gs)}"))
                continue
            for ax, (c, g) in enumerate(zip(cs, gs)):
                if c != g and ax not in _depth_axes(p):
                    expected.add((p, ax))
        got = set()
        for p, segs in spec.items():
            gs = gflat.get(p)
            if gs is None:
                out.append(Finding(
                    "contracts", "segment-spec", where, 0,
                    f"spec names unknown leaf '{'/'.join(p)}'"))
                continue
            cs = cflat.get(p)
            for seg in segs:
                ax = seg.axis % len(gs)
                got.add((p, ax))
                ids = np.asarray(seg.ids)
                if len(ids) != gs[ax]:
                    out.append(Finding(
                        "contracts", "segment-ids", where, 0,
                        f"leaf '{'/'.join(p)}' axis {ax}: {len(ids)} ids "
                        f"for union extent {gs[ax]}"))
                    continue
                n_segments = len(np.unique(ids))
                if cs is not None and n_segments != cs[ax]:
                    out.append(Finding(
                        "contracts", "segment-ids", where, 0,
                        f"leaf '{'/'.join(p)}' axis {ax}: {n_segments} "
                        f"distinct segments for client extent {cs[ax]}"))
                counts = seg.counts
                if counts.min() < 1:
                    out.append(Finding(
                        "contracts", "segment-counts", where, 0,
                        f"leaf '{'/'.join(p)}' axis {ax}: non-positive "
                        "segment size"))
                # each segment contributes exactly one client coordinate:
                # sum over union positions of 1/c_j == #segments
                total = float(np.sum(1.0 / counts))
                if abs(total - n_segments) > 1e-6:
                    out.append(Finding(
                        "contracts", "segment-counts", where, 0,
                        f"leaf '{'/'.join(p)}' axis {ax}: Σ 1/c_j = "
                        f"{total:.4f} != {n_segments} segments — counts "
                        "inconsistent with ids"))
        for p, ax in sorted(expected - got):
            out.append(Finding(
                "contracts", "segment-coverage", where, 0,
                f"width-differing axis {ax} of leaf '{'/'.join(p)}' is "
                "not covered by segment_spec"))
        for p, ax in sorted(got - expected):
            out.append(Finding(
                "contracts", "segment-coverage", where, 0,
                f"segment_spec emits axis {ax} of leaf '{'/'.join(p)}' "
                "where client and union extents agree"))
    return out


def check_coverage(case: Case) -> List[Finding]:
    """Mask algebra on constant pushes (no model evaluation): masks are
    0/1, loose ⊇ strict, loose == loosen(strict, filler), and landing
    sites are disjoint from nonzero filler."""
    out: List[Finding] = []
    fam = case.family
    union = fam.union(list(case.client_cfgs))
    for ci, cfg in enumerate(case.client_cfgs):
        where = f"{case.name}/client{ci}"
        strict, filler = coverage_and_filler(fam, cfg, union, seed=SEED)
        loose = coverage_mask(fam, cfg, union, policy="loose", seed=SEED)
        derived = loosen(strict, filler)
        for (path, s), (_, l), (_, d), (_, f) in zip(
                *(jax.tree_util.tree_flatten_with_path(t)[0]
                  for t in (strict, loose, derived, filler))):
            name = "/".join(path_keys(path))
            s, l, d, f = (np.asarray(x, np.float32) for x in (s, l, d, f))
            if not np.isin(s, (0.0, 1.0)).all():
                out.append(Finding("contracts", "mask-01", where, 0,
                                   f"strict mask of '{name}' is not 0/1"))
            if not np.isin(l, (0.0, 1.0)).all():
                out.append(Finding("contracts", "mask-01", where, 0,
                                   f"loose mask of '{name}' is not 0/1"))
            if (l < s).any():
                out.append(Finding(
                    "contracts", "coverage-superset", where, 0,
                    f"loose mask of '{name}' drops strictly-covered "
                    "coordinates (loose ⊉ strict)"))
            if (l != d).any():
                out.append(Finding(
                    "contracts", "coverage-loosen", where, 0,
                    f"loose mask of '{name}' != loosen(strict, filler) — "
                    "up(ones) landing sites overlap nonzero filler"))
            if (s * f != 0.0).any():
                out.append(Finding(
                    "contracts", "coverage-disjoint", where, 0,
                    f"'{name}': nonzero filler on a strictly-covered "
                    "coordinate — up() is not linear + constant there"))
    return out


def check_multiplicity(case: Case) -> List[Finding]:
    """``multiplicity`` agrees with the segment metadata leaf-by-leaf."""
    out: List[Finding] = []
    fam = case.family
    union = fam.union(list(case.client_cfgs))
    gshapes = global_shapes(fam, union)
    for ci, cfg in enumerate(case.client_cfgs):
        where = f"{case.name}/client{ci}"
        spec = fam.segment_spec(cfg, union, seed=SEED)
        mult = multiplicity(fam, cfg, union, seed=SEED)
        strict, _ = coverage_and_filler(fam, cfg, union, seed=SEED)
        gflat = {p: s for p, s, _ in _flat_shapes(gshapes)}
        for (path, m), (_, s) in zip(
                jax.tree_util.tree_flatten_with_path(mult)[0],
                jax.tree_util.tree_flatten_with_path(strict)[0]):
            keys = path_keys(path)
            name = "/".join(keys)
            m = np.asarray(m, np.float32)
            s = np.asarray(s, np.float32)
            if (m < 1).any() or not np.array_equal(m, np.round(m)):
                out.append(Finding(
                    "contracts", "multiplicity", where, 0,
                    f"'{name}': multiplicity not an integer ≥ 1"))
            segs = spec.get(keys, [])
            expect = np.ones(gflat[keys], np.float32)
            for seg in segs:
                shape = [1] * len(gflat[keys])
                shape[seg.axis % len(shape)] = -1
                expect = expect * seg.counts.astype(np.float32).reshape(shape)
            if not np.array_equal(m, expect):
                out.append(Finding(
                    "contracts", "multiplicity", where, 0,
                    f"'{name}': multiplicity != product of segment "
                    "sizes from segment_spec"))
            if not segs and (m != 1).any():
                out.append(Finding(
                    "contracts", "multiplicity", where, 0,
                    f"'{name}': multiplicity > 1 on a leaf with no "
                    "segment metadata"))
            # NOTE: m > 1 off the strict mask is fine — segment counts
            # broadcast along the depth axis, and multiplicity is only
            # consumed under the mask (weight = w·m_cov/mu). The binding
            # invariant is that duplication never appears where the
            # client owns nothing on a leaf WITHOUT depth padding:
            if not _depth_axes(keys) and segs and \
                    ((m > 1) & (s != 1)).any():
                out.append(Finding(
                    "contracts", "multiplicity", where, 0,
                    f"'{name}': duplicated coordinate (m > 1) that the "
                    "strict mask does not cover on a depth-free leaf"))
    return out


def check_plane(case: Case) -> List[Finding]:
    """PlaneSpec layout identity + manifest round-trip for the cohort's
    union tree."""
    out: List[Finding] = []
    fam = case.family
    union = fam.union(list(case.client_cfgs))
    gshapes = global_shapes(fam, union)
    where = f"{case.name}/plane"
    spec = plane.PlaneSpec.from_tree(gshapes)
    sizes = spec.leaf_sizes()
    total = sum(sizes)
    if spec.size != total:
        out.append(Finding("contracts", "plane-size", where, 0,
                           f"spec.size {spec.size} != Σ leaf sizes {total}"))
    off = 0
    for o, n in zip(spec.offsets, sizes):
        if o != off:
            out.append(Finding("contracts", "plane-offsets", where, 0,
                               f"offset {o} != running total {off} — "
                               "leaves overlap or leave gaps"))
            break
        off += n
    # abstract: pack -> (P,) f32; unpack -> the global tree; pack again
    packed = jax.eval_shape(lambda t: plane.pack(t, spec), gshapes)
    if tuple(packed.shape) != (spec.size,) or packed.dtype != jnp.float32:
        out.append(Finding("contracts", "plane-pack", where, 0,
                           f"pack: {packed.shape}/{packed.dtype}, expected "
                           f"({spec.size},)/float32"))
    unpacked = jax.eval_shape(
        lambda x: plane.unpack(x, spec),
        jax.ShapeDtypeStruct((spec.size,), jnp.float32))
    out += _diff_trees("plane-unpack", unpacked, gshapes, case=where)
    repacked = jax.eval_shape(
        lambda x: plane.pack(plane.unpack(x, spec), spec),
        jax.ShapeDtypeStruct((spec.size,), jnp.float32))
    if tuple(repacked.shape) != (spec.size,):
        out.append(Finding("contracts", "plane-roundtrip", where, 0,
                           f"pack∘unpack: {repacked.shape} != "
                           f"({spec.size},)"))
    # exact identity at value level on all-f32 layouts (a handful of
    # reshape/concat dispatches on a small vector — no model math)
    if spec.all_f32:
        x = jnp.arange(spec.size, dtype=jnp.float32)
        y = plane.pack(plane.unpack(x, spec), spec)
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            out.append(Finding(
                "contracts", "plane-roundtrip", where, 0,
                "pack(unpack(x)) != x on an all-f32 layout"))
    # manifest serialization round-trips the layout exactly
    spec2 = plane.PlaneSpec.from_manifest(spec.to_manifest())
    for fld in ("paths", "shapes", "dtypes", "offsets", "size"):
        if getattr(spec, fld) != getattr(spec2, fld):
            out.append(Finding(
                "contracts", "plane-manifest", where, 0,
                f"from_manifest(to_manifest()) changed '{fld}'"))
    # stacked spec strips K and matches the unstacked layout
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((3,) + tuple(s.shape), s.dtype),
        gshapes)
    sspec, k = plane.PlaneSpec.from_stacked(stacked)
    if k != 3 or sspec.shapes != spec.shapes or sspec.offsets != spec.offsets:
        out.append(Finding("contracts", "plane-stacked", where, 0,
                           "from_stacked does not strip K to the "
                           "unstacked layout"))
    return out


def check_quant(case: Case) -> List[Finding]:
    """Wire-format algebra (core.quant) on the cohort's own plane size:
    bf16 encode→decode is exactly the bf16 cast, int8 error is bounded by
    half a quantization step per tile, the error-feedback identity
    ``deq(q) + e' == x + e`` holds exactly, masked encoding zeroes
    off-mask coordinates, and the payload byte accounting is consistent.
    A few vector ops on one (1, P) row — no model math."""
    from repro.core import quant
    out: List[Finding] = []
    fam = case.family
    union = fam.union(list(case.client_cfgs))
    spec = plane.PlaneSpec.from_tree(global_shapes(fam, union))
    where = f"{case.name}/quant"
    n, tile = spec.size, quant.DEFAULT_TILE
    rng = np.random.default_rng(SEED)
    x = jnp.asarray(rng.standard_normal((1, n)), jnp.float32)
    # bf16: the wire IS the cast
    vb, sb = quant.quantize(x, "bf16", tile=tile)
    if sb is not None or vb.dtype != jnp.bfloat16:
        out.append(Finding("contracts", "quant-bf16", where, 0,
                           "bf16 wire must be a scale-free bfloat16 cast"))
    db = np.asarray(quant.dequantize(vb, sb, tile=tile))
    want = np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32))
    if not np.array_equal(db, want):
        out.append(Finding("contracts", "quant-bf16", where, 0,
                           "dequantize(quantize(x, bf16)) != bf16 cast"))
    # int8: symmetric per-tile, error ≤ scale/2
    vq, sq = quant.quantize(x, "int8", tile=tile)
    if vq.dtype != jnp.int8 or sq.shape != (1, quant.n_tiles(n, tile)):
        out.append(Finding(
            "contracts", "quant-int8", where, 0,
            f"int8 wire: values {vq.dtype}, scales {tuple(sq.shape)} — "
            f"expected int8 values + (1, {quant.n_tiles(n, tile)}) scales"))
    dq = np.asarray(quant.dequantize(vq, sq, tile=tile))
    step = np.repeat(np.asarray(sq), tile, axis=1)[:, :n]
    if (np.abs(dq - np.asarray(x)) > step / 2 + 1e-7).any():
        out.append(Finding(
            "contracts", "quant-int8", where, 0,
            "int8 round-trip error exceeds half a quantization step"))
    # error feedback: deq(q) + e' == x + e exactly
    e = jnp.asarray(rng.standard_normal((1, n)) * 0.01, jnp.float32)
    vals, scales, e2 = quant.encode(x, e, "int8", tile=tile)
    lhs = np.asarray(quant.dequantize(vals, scales, tile=tile)) \
        + np.asarray(e2)
    if not np.array_equal(lhs, np.asarray(x + e)):
        out.append(Finding(
            "contracts", "quant-ef", where, 0,
            "error-feedback identity deq(q) + e' != x + e"))
    # masked encoding zeroes off-mask coordinates (values AND residual)
    mask = jnp.asarray(rng.integers(0, 2, (1, n)), jnp.float32)
    vm, sm, em = quant.encode(x, e, "int8", tile=tile, mask=mask)
    off = np.asarray(mask) == 0.0
    if np.asarray(vm)[off].any() or np.asarray(em)[off].any():
        out.append(Finding(
            "contracts", "quant-mask", where, 0,
            "masked encode leaks nonzero values or residual off-mask"))
    # payload accounting: dense = values + scales; sparse = covered count
    nt = quant.n_tiles(n, tile)
    if quant.payload_nbytes("int8", n, tile=tile) != n + 4 * nt:
        out.append(Finding("contracts", "quant-bytes", where, 0,
                           "dense int8 payload != n·1 + n_tiles·4 bytes"))
    cov = int(np.asarray(mask).sum())
    if quant.payload_nbytes("int8", n, tile=tile, covered=cov) \
            != cov + 4 * nt:
        out.append(Finding("contracts", "quant-bytes", where, 0,
                           "sparse int8 payload != covered·1 + n_tiles·4"))
    if quant.payload_nbytes("f32", n, tile=tile) != 4 * n:
        out.append(Finding("contracts", "quant-bytes", where, 0,
                           "f32 payload != n·4 bytes"))
    return out


def check_flash(case: Case) -> List[Finding]:
    """The two attention backends behind ``models/attention.py:attend``
    agree under abstract evaluation for every client config's attention
    geometry: the flash kernel path and ``blockwise_attention`` produce
    the same output shape/dtype for causal, sliding-window and cross
    calls, and the flash custom_vjp yields q/k/v cotangents matching the
    primal shapes. VGG cohorts have no attention — skipped."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import blockwise_attention
    out: List[Finding] = []
    if not isinstance(case.family, TransformerFamily):
        return out
    for ci, cfg in enumerate(case.client_cfgs):
        where = f"{case.name}/client{ci}"
        kv = cfg.n_kv_heads if cfg.n_kv_heads and \
            cfg.n_heads % cfg.n_kv_heads == 0 else 1
        g = cfg.n_heads // kv
        hd = cfg.resolved_head_dim
        B, Sq, Sk = 1, 48, 48
        q = jax.ShapeDtypeStruct((B, Sq, kv, g, hd), jnp.float32)
        k = jax.ShapeDtypeStruct((B, Sk, kv, hd), jnp.float32)
        v = jax.ShapeDtypeStruct((B, Sk, kv, hd), jnp.float32)
        qp = jax.ShapeDtypeStruct((Sq,), jnp.int32)
        kp = jax.ShapeDtypeStruct((Sk,), jnp.int32)
        for tag, causal, window in (("causal", True, 0),
                                    ("window", True, min(cfg.window, Sq)),
                                    ("cross", False, 0)):
            fo = jax.eval_shape(
                lambda q, k, v, qp, kp, c=causal, w=window: flash_attention(
                    q, k, v, qp, kp, causal=c, window=w,
                    use_kernel=True, interpret=True),
                q, k, v, qp, kp)
            bo = jax.eval_shape(
                lambda q, k, v, qp, kp, c=causal, w=window:
                    blockwise_attention(q, k, v, qp, kp, causal=c,
                                        window=w),
                q, k, v, qp, kp)
            if tuple(fo.shape) != tuple(bo.shape) or fo.dtype != bo.dtype:
                out.append(Finding(
                    "contracts", "flash-parity", where, 0,
                    f"attention[{tag}]: flash {fo.shape}/{fo.dtype} != "
                    f"blockwise {bo.shape}/{bo.dtype}"))
        grads = jax.eval_shape(
            lambda q, k, v: jax.grad(
                lambda q, k, v: flash_attention(
                    q, k, v, jnp.arange(Sq), jnp.arange(Sk), causal=True,
                    use_kernel=True, interpret=True
                ).astype(jnp.float32).sum(),
                argnums=(0, 1, 2))(q, k, v),
            q, k, v)
        for name, got, want in zip("qkv", grads, (q, k, v)):
            if tuple(got.shape) != tuple(want.shape) or \
                    got.dtype != want.dtype:
                out.append(Finding(
                    "contracts", "flash-vjp", where, 0,
                    f"flash d{name}: {got.shape}/{got.dtype} != primal "
                    f"{want.shape}/{want.dtype}"))
    return out


def check_representable(case: Case) -> List[Finding]:
    """The enumerated cohorts are the unified engine's domain — each
    must be segment-representable (the eligibility gate)."""
    if case.family.segment_representable(list(case.client_cfgs)):
        return []
    return [Finding("contracts", "representable", case.name, 0,
                    "cohort is not segment-representable — the contract "
                    "matrix no longer matches the engine's domain")]


CHECKS = (check_representable, check_updown, check_segment_spec,
          check_coverage, check_multiplicity, check_plane, check_quant,
          check_flash)


def check_case(case: Case) -> List[Finding]:
    out: List[Finding] = []
    for fn in CHECKS:
        try:
            out.extend(fn(case))
        except Exception as e:  # a crash in a check is itself a finding
            out.append(Finding("contracts", "check-crash", case.name, 0,
                               f"{fn.__name__} raised {type(e).__name__}: "
                               f"{e}"))
    return out


def check_all(*, quick: bool = False) -> Tuple[List[Finding], int]:
    """Run the whole matrix; returns (findings, number of cases)."""
    findings: List[Finding] = []
    cases = all_cases(quick=quick)
    for case in cases:
        findings.extend(check_case(case))
    return findings, len(cases)
