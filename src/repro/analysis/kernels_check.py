"""Pallas kernel validator — static checks on traced ``pallas_call`` specs.

Traces the ``kernels/fedavg`` public wrappers (plain, masked,
masked+mult, whole-plane) over representative shapes with
``jax.make_jaxpr`` — abstract evaluation, nothing launches — then walks
the jaxpr for ``pallas_call`` equations and validates each one's grid
mapping:

  * every block shape divides its array shape axis-by-axis (the kernels
    assume even tiling; ragged tiles would read garbage columns),
  * the grid covers the tiled axis exactly (``grid == array // block``
    on the tiled axis — no dropped or duplicated tiles),
  * tiled blocks are lane-aligned (last axis a multiple of 128) —
    whole-array blocks like the ``(K, 1)`` weight column are exempt,
  * the estimated VMEM footprint (Σ block bytes over all operands ×2 for
    the pipeline's double buffering) fits the per-backend budget,
  * the ops-layer padding contract holds: the wrapper's OUTPUT aval is
    the caller's unpadded shape while the ``pallas_call`` inside works
    on the lane/block-rounded extent — i.e. padded columns exist only
    between the pad and the final slice.

Representative shapes deliberately include lane-odd parameter counts
(exercising ``ops``'s pad-then-slice path), a sub-lane tensor, and a
multi-megabyte plane at the default block size.
"""
from __future__ import annotations

import math
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import Finding
from repro.kernels.fedavg import ops
from repro.kernels.fedavg.fedavg import LANE

VMEM_BUDGET_BYTES = {"tpu": 16 * 2 ** 20}   # per-core VMEM (pallas guide)
DOUBLE_BUFFER = 2                           # pipelined blocks are ×2


def _sds(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _subjaxprs(value):
    if hasattr(value, "jaxpr"):            # ClosedJaxpr
        yield value.jaxpr
    elif hasattr(value, "eqns"):           # Jaxpr
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _walk_eqns(sub)


def _block_shape(bm) -> Tuple[int, ...]:
    # mapped / squeezed dims show up as non-int sentinels — they occupy
    # one row/col, so count them as 1 for footprint and divisibility
    return tuple(int(b) if isinstance(b, int) else 1
                 for b in bm.block_shape)


def _check_pallas_eqn(name: str, eqn, *, backend: str = "tpu"
                      ) -> List[Finding]:
    out: List[Finding] = []
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    n_tiles = math.prod(grid) if grid else 1
    vmem = 0
    for i, bm in enumerate(gm.block_mappings):
        arr = tuple(int(s) for s in bm.array_shape_dtype.shape)
        blk = _block_shape(bm)
        where = f"{name}/operand{i}"
        if len(arr) != len(blk):
            out.append(Finding("kernels", "block-rank", where, 0,
                               f"block rank {len(blk)} != array rank "
                               f"{len(arr)}"))
            continue
        tiles = 1
        for ax, (a, b) in enumerate(zip(arr, blk)):
            if b <= 0 or a % b:
                out.append(Finding(
                    "kernels", "block-divisibility", where, 0,
                    f"axis {ax}: block {b} does not divide array extent "
                    f"{a} — ragged tile would stream garbage columns"))
            else:
                tiles *= a // b
        if blk and arr and blk[-1] != arr[-1] and blk[-1] % LANE:
            out.append(Finding(
                "kernels", "lane-alignment", where, 0,
                f"tiled last axis block {blk[-1]} is not a multiple of "
                f"the {LANE}-wide lane"))
        if tiles and n_tiles % tiles:
            # an operand may be tiled on a SUBSET of grid axes (the flash
            # kernels broadcast k/v blocks over the q-block axis and vice
            # versa), so each tile must be visited a whole number of
            # times: tile count divides the grid size
            out.append(Finding(
                "kernels", "grid-coverage", where, 0,
                f"operand tiles {tiles} do not divide the grid size "
                f"{n_tiles} — tiles dropped or duplicated"))
        vmem += math.prod(blk) * bm.array_shape_dtype.dtype.itemsize
    budget = VMEM_BUDGET_BYTES[backend]
    est = vmem * DOUBLE_BUFFER
    if est > budget:
        out.append(Finding(
            "kernels", "vmem-budget", name, 0,
            f"estimated VMEM footprint {est / 2**20:.2f} MiB "
            f"(double-buffered blocks) exceeds the {backend} budget "
            f"{budget / 2**20:.0f} MiB — shrink `block`"))
    return out


def _case_findings(name: str, fn: Callable, avals: Sequence,
                   expect_shape: Tuple[int, ...]) -> List[Finding]:
    try:
        closed = jax.make_jaxpr(fn)(*avals)
    except Exception as e:
        return [Finding("kernels", "trace-crash", name, 0,
                        f"tracing raised {type(e).__name__}: {e}")]
    out: List[Finding] = []
    pallas = [e for e in _walk_eqns(closed.jaxpr)
              if e.primitive.name == "pallas_call"]
    if not pallas:
        out.append(Finding("kernels", "no-kernel", name, 0,
                           "no pallas_call in the traced jaxpr — the "
                           "wrapper silently fell back off the kernel"))
    for eqn in pallas:
        out.extend(_check_pallas_eqn(name, eqn))
    got = tuple(int(s) for s in closed.out_avals[0].shape)
    if got != tuple(expect_shape):
        out.append(Finding(
            "kernels", "pad-slice", name, 0,
            f"wrapper output {got} != caller shape {tuple(expect_shape)} "
            "— padded columns leak out of the kernel"))
    return out


def cases():
    """(name, fn, avals, expected output shape) — the kernel surface ×
    representative shapes. ``interpret=True`` + ``use_kernel=True`` so
    the pallas path traces identically on CPU CI and TPU."""
    K = 8
    n_odd = 4096 * 3 + 517        # lane-odd plane -> pad-then-slice path
    n_even = 4096 * 4             # block-aligned plane -> zero padding
    n_big = 1 << 22               # ~128 MiB of stacked params, K=8
    x = lambda n: _sds(K, n)      # noqa: E731
    w = _sds(K)
    for n in (n_odd, n_even, n_big):
        yield (f"plane_agg/N={n}",
               lambda p, wt, n=n: ops.plane_agg(
                   p, wt, use_kernel=True, interpret=True),
               (x(n), w), (n,))
        yield (f"plane_agg_masked/N={n}",
               lambda p, wt, m, n=n: ops.plane_agg(
                   p, wt, masks=m, use_kernel=True, interpret=True),
               (x(n), w, x(n)), (n,))
        yield (f"plane_agg_mult_fb/N={n}",
               lambda p, wt, m, mu, fb, n=n: ops.plane_agg(
                   p, wt, masks=m, mult=mu, fallback=fb,
                   use_kernel=True, interpret=True),
               (x(n), w, x(n), x(n), _sds(n)), (n,))
    # streaming surface (DESIGN.md §9): the chunked accumulate + finish
    # pair behind fedavg_stacked(layout="stream"). Kc is a CHUNK of
    # client rows (smaller than any realistic cohort — the chunk
    # boundary is the contract), the buffers are (n,); shapes hit the
    # lane-odd pad-then-slice path, an even plane, and a multi-MiB
    # accumulator at the auto-selected block.
    Kc = 4
    a = _sds  # (n,) accumulator aval
    for n in (n_odd, n_even, n_big):
        yield (f"plane_accum/N={n}",
               lambda nm, dn, cv, c, wt: ops.plane_accum(
                   nm, dn, cv, c, wt, use_kernel=True, interpret=True),
               (a(n), a(n), a(n), _sds(Kc, n), _sds(Kc)), (n,))
        yield (f"plane_accum_masked_mult/N={n}",
               lambda nm, dn, cv, c, wt, m, mu: ops.plane_accum(
                   nm, dn, cv, c, wt, masks=m, mult=mu,
                   use_kernel=True, interpret=True),
               (a(n), a(n), a(n), _sds(Kc, n), _sds(Kc), _sds(Kc, n),
                _sds(Kc, n)), (n,))
        yield (f"plane_finish/N={n}",
               lambda nm, dn, cv, fb: ops.plane_finish(
                   nm, dn, cv, fallback=fb, use_kernel=True,
                   interpret=True),
               (a(n), a(n), a(n), a(n)), (n,))
    # quantized-wire surface (DESIGN.md §10): the fused
    # dequantize-accumulate pass behind wire="int8". int8 chunk rows +
    # a per-tile f32 scale grid (whole-array resident operand); same
    # lane-odd / even / multi-MiB planes as the f32 streaming cases.
    tile = 256
    nt = lambda n: -(-n // tile)  # noqa: E731
    for n in (n_odd, n_even, n_big):
        yield (f"plane_accum_q/N={n}",
               lambda nm, dn, cv, c, s, wt: ops.plane_accum_q(
                   nm, dn, cv, c, s, wt, tile=tile,
                   use_kernel=True, interpret=True),
               (a(n), a(n), a(n), _sds(Kc, n, dtype=jnp.int8),
                _sds(Kc, nt(n)), _sds(Kc)), (n,))
        yield (f"plane_accum_q_masked_mult/N={n}",
               lambda nm, dn, cv, c, s, wt, m, mu: ops.plane_accum_q(
                   nm, dn, cv, c, s, wt, masks=m, mult=mu, tile=tile,
                   use_kernel=True, interpret=True),
               (a(n), a(n), a(n), _sds(Kc, n, dtype=jnp.int8),
                _sds(Kc, nt(n)), _sds(Kc), _sds(Kc, n), _sds(Kc, n)),
               (n,))
        yield (f"plane_accum_q_fold/N={n}",
               lambda nm, dn, cv, c, s, wt, m, b: ops.plane_accum_q(
                   nm, dn, cv, c, s, wt, masks=m, base=b, tile=tile,
                   use_kernel=True, interpret=True),
               (a(n), a(n), a(n), _sds(Kc, n, dtype=jnp.int8),
                _sds(Kc, nt(n)), _sds(Kc), _sds(Kc, n), a(n)), (n,))
    # flash-attention surface (DESIGN.md §11): the training forward plus
    # the custom_vjp backward (dQ and dK/dV recomputation kernels, traced
    # through jax.grad so the bwd pallas_calls appear in the jaxpr).
    # Shapes: lane-aligned causal GQA, a sliding-window band, a lane-odd
    # head dim (hd=72 -> whole-axis last blocks), and a sub-lane short
    # sequence (bq=8 rows).
    from repro.kernels.flash_attention import ops as fops

    def _flash_avals(B, Sq, Sk, KV, G, hd):
        return (_sds(B, Sq, KV, G, hd), _sds(B, Sk, KV, hd),
                _sds(B, Sk, KV, hd), _sds(Sq, dtype=jnp.int32),
                _sds(Sk, dtype=jnp.int32))

    flash_shapes = (
        ("causal_gqa", (2, 256, 256, 2, 4, 128), True, 0),
        ("window", (1, 256, 256, 1, 8, 64), True, 64),
        ("cross_laneodd", (2, 128, 192, 2, 1, 72), False, 0),
        ("sublane", (1, 8, 8, 2, 2, 64), True, 0),
    )
    for tag, (B, Sq, Sk, KV, G, hd), causal, window in flash_shapes:
        def fwd_fn(q, k, v, qp, kp, *, c=causal, w=window):
            return fops.flash_attention(q, k, v, qp, kp, causal=c,
                                        window=w, use_kernel=True,
                                        interpret=True)

        def bwd_fn(q, k, v, qp, kp, *, c=causal, w=window):
            def loss(q, k, v):
                return fops.flash_attention(
                    q, k, v, qp, kp, causal=c, window=w, use_kernel=True,
                    interpret=True).sum()
            return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

        yield (f"flash_fwd/{tag}", fwd_fn, _flash_avals(B, Sq, Sk, KV, G, hd),
               (B, Sq, KV * G, hd))
        yield (f"flash_bwd/{tag}", bwd_fn, _flash_avals(B, Sq, Sk, KV, G, hd),
               (B, Sq, KV, G, hd))
    # leaf-shaped wrappers: lane-odd tensor + sub-lane tensor
    for shape in ((33, 7), (5,), (256, 130)):
        n = math.prod(shape)
        yield (f"weighted_sum/{shape}",
               lambda s, wt: ops.weighted_sum(s, wt, interpret=True),
               (_sds(K, *shape), w), shape)
        yield (f"weighted_sum_masked/{shape}",
               lambda s, wt, m: ops.weighted_sum_masked(
                   s, wt, m, interpret=True),
               (_sds(K, *shape), w, _sds(K, *shape)), shape)
        yield (f"weighted_sum_masked_mult/{shape}",
               lambda s, wt, m, mu: ops.weighted_sum_masked(
                   s, wt, m, mult=mu, interpret=True, renorm=False),
               (_sds(K, *shape), w, _sds(K, *shape), _sds(K, *shape)),
               shape)


def check_all() -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    n = 0
    for name, fn, avals, expect in cases():
        findings.extend(_case_findings(name, fn, avals, expect))
        n += 1
    return findings, n
