"""repro.analysis — static contract verification + JAX-aware lint (fedlint).

FedADP's correctness rests on algebraic invariants (up/down round-trips,
E·Eᵀ idempotence, coverage/multiplicity consistency, PlaneSpec layout
identity) that the tier-1 suite only exercises dynamically, minutes at a
time. This package proves the *static* half of those contracts in
seconds — ``jax.eval_shape`` abstract evaluation, AST inspection, jaxpr
introspection — with zero training steps executed, so it can gate every
PR before the heavy tests run. Four passes:

  * ``contracts``  — the architecture-matrix contract checker
                     (``analysis.contracts``): every registry
                     architecture × both families, under abstract
                     evaluation only.
  * ``lint``       — fedlint (``analysis.lint``): AST rules for JAX
                     hazards the ruff gate cannot express (FDL001-004),
                     with inline ``# fedlint: ignore[RULE]``
                     suppressions.
  * ``kernels``    — the Pallas kernel validator
                     (``analysis.kernels_check``): grid/block
                     divisibility, lane alignment, padded-column
                     handling and an estimated VMEM footprint per
                     backend budget, read off traced ``pallas_call``
                     specs without launching anything.
  * ``retrace``    — the jit-cache-miss detector (``analysis.retrace``):
                     a context manager counting XLA compilations, used
                     by tests to prove ``Federation.run`` compiles
                     nothing after round 1. Not part of the default CLI
                     run (it executes a real federation).

Entry points: ``python -m repro.analysis`` and ``tools/fedlint.py``
(same flags). Exit code 0 = no findings.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Finding:
    """One verified defect or contract violation.

    ``where`` is a file path for lint findings and a logical location
    (``family/cohort/client`` or ``kernel/case``) for the abstract
    passes; ``line`` is 0 when there is no source position.
    """
    pass_name: str           # "contracts" | "lint" | "kernels" | "retrace"
    rule: str                # e.g. "FDL001", "updown-shape", "vmem-budget"
    where: str
    line: int
    msg: str

    def format(self) -> str:
        loc = f"{self.where}:{self.line}" if self.line else self.where
        return f"{loc}: [{self.rule}] {self.msg}"


@dataclass
class Report:
    """Aggregate of one analysis run: findings + per-pass case counts."""
    findings: List[Finding] = field(default_factory=list)
    checked: Dict[str, int] = field(default_factory=dict)   # pass -> cases

    def extend(self, pass_name: str, findings: List[Finding],
               n_cases: int) -> None:
        self.findings.extend(findings)
        self.checked[pass_name] = self.checked.get(pass_name, 0) + n_cases

    @property
    def ok(self) -> bool:
        return not self.findings

    def summary_lines(self) -> List[str]:
        out = []
        for name, n in sorted(self.checked.items()):
            bad = sum(1 for f in self.findings if f.pass_name == name)
            status = "ok" if bad == 0 else f"{bad} finding(s)"
            out.append(f"{name}: {n} case(s) checked — {status}")
        return out


PASSES: Tuple[str, ...] = ("contracts", "lint", "kernels")


def run(passes: Optional[List[str]] = None, *, lint_roots=None,
        quick: bool = False) -> Report:
    """Run the requested passes (default: all static ones) and return
    the aggregate :class:`Report`. Imports are deferred per pass so the
    lint pass stays usable without a working jax install."""
    report = Report()
    for name in passes or list(PASSES):
        if name == "contracts":
            from repro.analysis import contracts
            findings, n = contracts.check_all(quick=quick)
        elif name == "lint":
            from repro.analysis import lint
            findings, n = lint.lint_roots(lint_roots)
        elif name == "kernels":
            from repro.analysis import kernels_check
            findings, n = kernels_check.check_all()
        else:
            raise ValueError(f"unknown analysis pass {name!r}; known: "
                             f"{PASSES}")
        report.extend(name, findings, n)
    return report
