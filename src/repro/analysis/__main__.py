"""CLI: ``python -m repro.analysis`` — run the static passes, exit 0 on
a clean repo. ``tools/fedlint.py`` is the same entry point."""
from __future__ import annotations

import argparse
import sys
import time

from repro.analysis import PASSES, run


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="Static contract verifier + JAX-aware lint (fedlint) "
                    "for the FedADP stack. Exit code 0 = no findings.")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASSES, metavar="PASS",
                    help="run only this pass (repeatable); default: all "
                         f"of {', '.join(PASSES)}")
    ap.add_argument("--lint-root", dest="lint_roots", action="append",
                    metavar="PATH",
                    help="file or directory for the lint pass "
                         "(repeatable); default: src/")
    ap.add_argument("--quick", action="store_true",
                    help="contracts: check the VGG cohort + two "
                         "transformer architectures instead of the full "
                         "registry matrix")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    report = run(args.passes, lint_roots=args.lint_roots, quick=args.quick)
    dt = time.perf_counter() - t0

    for f in report.findings:
        print(f.format())
    for line in report.summary_lines():
        print(line)
    total = sum(report.checked.values())
    status = "clean" if report.ok else f"{len(report.findings)} finding(s)"
    print(f"repro.analysis: {total} case(s), {status}, {dt:.1f}s")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
