"""Self-contained optimizers (no optax dependency).

An ``Optimizer`` is a pair of pure functions:
  init(params)                       -> state
  update(grads, state, params, step) -> (new_params, new_state)

AdamW keeps fp32 master copies of bf16 params (mixed-precision training on
the TPU target); SGD matches the paper's local-update rule (Eq. 3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def _as_schedule(lr: Union[float, Schedule]) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def sgd(lr: Union[float, Schedule], momentum: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step=0):
        lr_t = sched(jnp.asarray(step))
        if momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: (p - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new, state
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                          state["mu"], grads)
        new = jax.tree.map(
            lambda p, m: (p - lr_t * m.astype(jnp.float32)).astype(p.dtype),
            params, mu)
        return new, {"mu": mu}

    return Optimizer(init, update)


def adamw(lr: Union[float, Schedule], b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          master_fp32: bool = True) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        state = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
        if master_fp32:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def update(grads, state, params, step=0):
        step = jnp.asarray(step, jnp.int32)
        lr_t = sched(step)
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        masters = state.get("master", params)

        def step_fn(p32, m_, v_):
            upd = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            p32f = p32.astype(jnp.float32)
            return p32f - lr_t * (upd + weight_decay * p32f)

        new_master = jax.tree.map(step_fn, masters, m, v)
        new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                                  new_master, params)
        new_state = {"m": m, "v": v}
        if master_fp32:
            new_state["master"] = new_master
        return new_params, new_state

    return Optimizer(init, update)
