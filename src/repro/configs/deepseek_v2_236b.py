"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

Source: arXiv:2405.04434; 60L d_model=5120 128H d_ff=1536 (routed expert
width) vocab=102400. MLA compresses the KV cache but attention is still
full => long_500k skipped (cache *would* fit; see DESIGN.md §6).

Deviation from source model: DeepSeek-V2's first layer is a dense FFN
(d_ff=12288); we use MoE in every layer for stacking uniformity (noted).
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,              # qk_nope(128)+qk_rope(64); v_head_dim=128
    d_ff=1536,
    vocab_size=102400,
    layer_pattern=("global",),
    mlp_kind="swiglu",
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared=2, d_ff_shared=1536),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    rope_theta=10_000.0,
    tie_embeddings=False,
    sub_quadratic=False,
    source="arXiv:2405.04434",
)
