"""Config registry: ``get_config(arch_id)`` for every assigned architecture."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    EncoderConfig,
    FrontendConfig,
    InputShape,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    active_param_count,
    param_count,
    reduced,
)

ARCH_IDS = (
    "gemma3-27b",
    "glm4-9b",
    "mixtral-8x7b",
    "xlstm-125m",
    "command-r-plus-104b",
    "deepseek-v2-236b",
    "gemma-7b",
    "recurrentgemma-9b",
    "whisper-small",
    "internvl2-1b",
)

_MODULES: Dict[str, str] = {a: a.replace("-", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
