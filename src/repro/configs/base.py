"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. The model
substrate (``repro.models``) is driven entirely by these configs; the
FedADP core (``repro.core``) manipulates *derived* configs (narrower /
shallower client variants) of the same families.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

# Layer kinds usable in ``layer_pattern`` (the repeating unit):
#   "global"  - full causal self-attention
#   "local"   - sliding-window causal self-attention (cfg.window)
#   "rglru"   - RG-LRU recurrent block (Griffin / RecurrentGemma)
#   "mlstm"   - xLSTM matrix-memory block
#   "slstm"   - xLSTM scalar-memory block
#   "crossdec"- decoder block with self-attn + cross-attn (whisper decoder)
LAYER_KINDS = ("global", "local", "rglru", "mlstm", "slstm", "crossdec")

ATTN_KINDS = ("global", "local", "crossdec")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared (always-on) experts
    d_ff_shared: int = 0       # d_ff of EACH shared expert
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_rnn: int = 0             # recurrent width (rglru); 0 => d_model
    conv_width: int = 4
    n_heads: int = 4           # xLSTM heads


@dataclass(frozen=True)
class EncoderConfig:
    """Bidirectional encoder (whisper). Frontend embeddings are a stub."""
    n_layers: int
    n_ctx: int                 # e.g. 1500 mel frames after conv stride
    d_model: int


@dataclass(frozen=True)
class FrontendConfig:
    kind: str                  # "audio" | "vision"
    n_prefix: int = 0          # number of prefix embedding tokens (vision)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str             # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 => d_model // n_heads
    layer_pattern: Tuple[str, ...] = ("global",)
    window: int = 4096         # sliding window for "local" layers
    mlp_kind: str = "swiglu"   # swiglu | geglu | gelu
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    qkv_bias: bool = False
    mlp_bias: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling
    logit_softcap: float = 0.0
    sub_quadratic: bool = False  # eligible for the long_500k decode shape
    source: str = ""           # citation (paper / model card)
    dtype: str = "float32"     # compute/param dtype ("bfloat16" for dry-runs)

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_rnn(self) -> int:
        if self.ssm is None:
            return self.d_model
        return self.ssm.d_rnn or self.d_model

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_units(self) -> int:
        return self.n_layers // self.pattern_len

    @property
    def rem_kinds(self) -> Tuple[str, ...]:
        return self.layer_pattern[: self.n_layers % self.pattern_len]

    def layer_kinds(self) -> Tuple[str, ...]:
        """Kind of every layer, in order."""
        full = self.layer_pattern * self.n_units + self.rem_kinds
        assert len(full) == self.n_layers
        return full

    def with_dtype(self, dtype: str) -> "ModelConfig":
        return replace(self, dtype=dtype)

    def validate(self) -> None:
        for k in self.layer_pattern:
            assert k in LAYER_KINDS, k
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.mla
        if self.arch_type == "moe":
            assert self.moe is not None
        if self.arch_type in ("ssm", "hybrid"):
            assert any(k in ("rglru", "mlstm", "slstm") for k in self.layer_pattern)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for MODEL_FLOPS = 6*N*D roofline)."""
    from repro.models.transformer import init_params  # lazy, avoids cycle
    import jax
    import numpy as np

    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))


def active_param_count(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: shared + top_k routed experts)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    inactive = (m.n_experts - m.top_k) * per_expert * _n_moe_layers(cfg)
    return total - inactive


def _n_moe_layers(cfg: ModelConfig) -> int:
    # MoE replaces the MLP in every attention-bearing layer.
    return sum(1 for k in cfg.layer_kinds() if k in ATTN_KINDS)


def reduced(cfg: ModelConfig, *, d_model: int = 256, n_units: int = 1,
            seed_vocab: int = 512) -> ModelConfig:
    """Smoke-test variant: same family, tiny dims (<=512 d_model, <=4 experts,
    n_layers = one pattern unit (plus remainder-free))."""
    plen = cfg.pattern_len
    n_layers = max(2, plen) * n_units if plen >= 2 else 2 * n_units
    # keep layer kinds from the same family
    scale = d_model / cfg.d_model
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = max(8, d_model // n_heads)
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=max(8, int(cfg.d_ff * scale)) if cfg.d_ff else 0,
        vocab_size=seed_vocab,
        window=min(cfg.window, 64),
        dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = replace(
            cfg.moe,
            n_experts=min(4, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=max(8, int(cfg.moe.d_ff_expert * scale)),
            n_shared=min(1, cfg.moe.n_shared),
            d_ff_shared=max(8, int(cfg.moe.d_ff_shared * scale)) if cfg.moe.n_shared else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        kw["head_dim"] = 16
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_rnn=d_model if cfg.ssm.d_rnn else 0,
                            n_heads=min(2, cfg.ssm.n_heads))
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_ctx=16, d_model=d_model)
    if cfg.frontend is not None:
        kw["frontend"] = replace(cfg.frontend,
                                 n_prefix=min(8, cfg.frontend.n_prefix) or 0)
    return replace(cfg, **kw)
