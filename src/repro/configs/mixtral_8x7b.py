"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

Source: arXiv:2401.04088; 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096 => long_500k-eligible.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    layer_pattern=("local",),
    window=4096,
    mlp_kind="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    sub_quadratic=True,
    source="arXiv:2401.04088",
)
