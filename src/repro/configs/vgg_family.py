"""The paper's own experimental family: VGG-13..VGG-19(-Wider) variants.

Section IV of FedADP: 8 architecture types — VGG-13, VGG-14, VGG-15,
VGG-16-Wider, VGG-17, VGG-18, VGG-19, VGG-19-Wider — across 20 clients
(6 clients on VGG-19, 2 on each of the other 7).

We express a VGG variant as a ``VGGConfig``: a tuple of conv stages, each
stage a tuple of channel widths (one entry per conv layer; max-pool after
every stage), followed by a classifier MLP. "-Wider" widens one layer of
the corresponding base net (the paper's Fig. 1 highlights the widened
layers) — we widen the last conv layer of stage 4 by 1.5x, rounded to a
multiple of 16, matching the illustrated pattern.

The *global* architecture of the cohort is the elementwise union
(max depth per stage, max width per layer) => VGG-19-Wider, exactly as
the paper states.

For the offline reproduction (repro band 2/5: CIFAR/MNIST not available)
we additionally provide ``scaled(cfg, f)`` reduced variants used with the
synthetic datasets; the architectural *relationships* between variants
(which layers are missing / narrower) are preserved exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class VGGConfig:
    name: str
    # conv stages: one tuple per stage, entries = output channels per conv
    stages: Tuple[Tuple[int, ...], ...]
    classifier: Tuple[int, ...] = (4096, 4096)
    n_classes: int = 10
    in_channels: int = 3
    image_size: int = 32


def _wider(stages, stage_idx=3, layer_idx=0, factor=1.5):
    # layer_idx=0: depth variants align at the front (To-Deeper appends
    # missing layers at the END of a stage), so widening the first conv of
    # stage 4 makes union(cohort) == VGG-19-Wider exactly as the paper says.
    st = [list(s) for s in stages]
    w = st[stage_idx][layer_idx]
    st[stage_idx][layer_idx] = int(round(w * factor / 16) * 16)
    return tuple(tuple(s) for s in st)


_C = (64, 128, 256, 512, 512)  # canonical VGG stage widths

# layers-per-stage for each depth variant (conv counts; totals = depth-3 FC)
_DEPTHS = {
    "vgg13": (2, 2, 2, 2, 2),
    "vgg14": (2, 2, 3, 2, 2),
    "vgg15": (2, 2, 3, 3, 2),
    "vgg16": (2, 2, 3, 3, 3),
    "vgg17": (2, 2, 4, 3, 3),
    "vgg18": (2, 2, 4, 4, 3),
    "vgg19": (2, 2, 4, 4, 4),
}


def _mk(name: str, depths, wider: bool = False, **kw) -> VGGConfig:
    stages = tuple(tuple(_C[i] for _ in range(n)) for i, n in enumerate(depths))
    if wider:
        stages = _wider(stages)
    return VGGConfig(name=name, stages=stages, **kw)


def vgg(name: str, **kw) -> VGGConfig:
    base, _, suffix = name.partition("-")
    return _mk(name, _DEPTHS[base], wider=(suffix == "wider"), **kw)


# The paper's 8-architecture cohort.
PAPER_COHORT = (
    "vgg13", "vgg14", "vgg15", "vgg16-wider",
    "vgg17", "vgg18", "vgg19", "vgg19-wider",
)

# client -> architecture assignment: 6 clients on VGG-19, 2 on each other.
def paper_client_archs() -> Tuple[str, ...]:
    out = []
    for a in PAPER_COHORT:
        out.extend([a] * (6 if a == "vgg19" else 2))
    assert len(out) == 20
    return tuple(out)


def union_config(cfgs) -> VGGConfig:
    """Global architecture = union (max depth per stage, max width per layer,
    elementwise) of the cohort — Section III.B of the paper."""
    n_stages = max(len(c.stages) for c in cfgs)
    stages = []
    for si in range(n_stages):
        depth = max(len(c.stages[si]) for c in cfgs if si < len(c.stages))
        layer_ws = []
        for li in range(depth):
            ws = [c.stages[si][li] for c in cfgs
                  if si < len(c.stages) and li < len(c.stages[si])]
            layer_ws.append(max(ws))
        stages.append(tuple(layer_ws))
    cls_depth = max(len(c.classifier) for c in cfgs)
    classifier = tuple(
        max(c.classifier[i] for c in cfgs if i < len(c.classifier))
        for i in range(cls_depth))
    c0 = cfgs[0]
    return VGGConfig(name="union", stages=tuple(stages), classifier=classifier,
                     n_classes=c0.n_classes, in_channels=c0.in_channels,
                     image_size=c0.image_size)


def scaled(cfg: VGGConfig, f: float = 0.125, classifier: int = 128) -> VGGConfig:
    """Reduced-width variant for offline (synthetic-data) experiments.

    Widths scale by ``f`` (rounded to multiples of 4 so that the wider
    variants stay strictly wider); depth structure is preserved exactly.
    """
    def r(w):
        return max(4, int(round(w * f / 4) * 4))
    stages = tuple(tuple(r(w) for w in s) for s in cfg.stages)
    cls = tuple(classifier for _ in cfg.classifier)
    return replace(cfg, name=cfg.name + f"-x{f}", stages=stages, classifier=cls)
