"""glm4-9b [dense] — RoPE, GQA kv=2.

Source: hf:THUDM/glm-4-9b; 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552. GLM-4 uses QKV bias; pure full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    arch_type="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    layer_pattern=("global",),
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    qkv_bias=True,
    sub_quadratic=False,
    source="hf:THUDM/glm-4-9b",
)
