"""gemma-7b [dense] — GeGLU, head_dim=256.

Source: arXiv:2403.08295; 28L d_model=3072 16H (kv=16; MQA is on the 2b)
d_ff=24576 vocab=256000. Full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    layer_pattern=("global",),
    mlp_kind="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
    sub_quadratic=False,
    source="arXiv:2403.08295",
)
