"""command-r-plus-104b [dense] — GQA, no-bias.

Source: hf:CohereForAI/c4ai-command-r-v01 (family card); 64L d_model=12288
96H (GQA kv=8) d_ff=33792 vocab=256000. Full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    layer_pattern=("global",),
    mlp_kind="swiglu",
    rope_theta=75_000_000.0,
    tie_embeddings=True,
    sub_quadratic=False,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
