"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 ratio.

Source: arXiv:2402.19427 (Griffin); 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000. Pattern (rglru, rglru, local) — "1:2" attention:
recurrent ratio. O(1) recurrent state + windowed attention =>
long_500k-eligible.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048,
    mlp_kind="geglu",
    ssm=SSMConfig(d_rnn=4096, conv_width=4),
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
    sub_quadratic=True,
    source="arXiv:2402.19427",
)
