"""internvl2-1b [vlm] — InternViT frontend STUBBED + Qwen2-0.5B LM backbone.

Source: arXiv:2404.16821; LM backbone 24L d_model=896 14H (GQA kv=2)
d_ff=4864 vocab=151655. The InternViT vision encoder + MLP projector is a
stub per the assignment: ``input_specs`` provides 256 precomputed patch
embeddings of shape (B, 256, 896) that are prepended to the token stream.
Full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    layer_pattern=("global",),
    mlp_kind="swiglu",
    frontend=FrontendConfig(kind="vision", n_prefix=256),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    sub_quadratic=False,
    source="arXiv:2404.16821",
)
