"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

Source: hf:google/gemma-3-1b-pt (family card); 62L d_model=5376 32H
(GQA kv=16) d_ff=21504 vocab=262144. head_dim=128 per the Gemma 3 report.
Sliding-window (1024) local layers make it long_500k-eligible.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    layer_pattern=("local",) * 5 + ("global",),
    window=1024,
    mlp_kind="geglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale=True,
    sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt",
)
