"""whisper-small [audio] — encoder-decoder, conv/mel frontend STUBBED.

Source: arXiv:2212.04356; 12L (decoder) d_model=768 12H d_ff=3072
vocab=51865; 12-layer bidirectional encoder over 1500 frame embeddings.
The mel-spectrogram + conv feature extractor is a stub per the assignment:
``input_specs`` provides precomputed (B, 1500, 768) frame embeddings.

Backbone deviation (noted in DESIGN.md): RoPE instead of learned absolute
positions. Decode shapes lower the DECODER step (self-KV cache of the
assigned seq_len + fixed cross-KV); full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig, EncoderConfig, FrontendConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    layer_pattern=("crossdec",),
    mlp_kind="gelu",
    encoder=EncoderConfig(n_layers=12, n_ctx=1500, d_model=768),
    frontend=FrontendConfig(kind="audio"),
    qkv_bias=True,
    mlp_bias=True,
    tie_embeddings=True,
    sub_quadratic=False,
    source="arXiv:2212.04356",
)
