"""xlstm-125m [ssm] — sLSTM + mLSTM blocks.

Source: arXiv:2405.04517; 12L d_model=768 4H d_ff=0 (blocks carry their
own projections) vocab=50304. Pattern 3x mLSTM : 1x sLSTM (xLSTM[.:1]
style ratio). Recurrent => O(1) decode state, long_500k-eligible.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm=SSMConfig(n_heads=4, conv_width=4),
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.04517",
)
