from repro.kernels.fedavg import ops, ref  # noqa: F401
