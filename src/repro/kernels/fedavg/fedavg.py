"""Pallas TPU kernel: weighted multi-model parameter aggregation.

FedAvg's inner loop (paper Eq. 1) is a memory-bound streaming reduction
over K stacked client parameter tensors: out[n] = sum_k w[k] * x[k, n].
The kernel tiles the flattened parameter axis into VMEM-resident blocks
(lane-aligned, 128 multiple) and keeps the K axis resident, so every HBM
byte is touched exactly once (arithmetic intensity ~= 1 FLOP/byte — see
the roofline discussion in EXPERIMENTS.md).

TARGET: TPU (pl.pallas_call + BlockSpec). ``interpret=None`` auto-selects:
compiled (interpret=False) on a TPU backend, interpreter mode elsewhere —
so the same call site is production-fast on TPU and still validated via
interpret=True on CPU against ``ref.weighted_sum_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel(x_ref, w_ref, o_ref):
    # x_ref: (K, T) block; w_ref: (K, 1); o_ref: (1, T)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True).astype(o_ref.dtype)


def _masked_kernel(x_ref, w_ref, m_ref, o_ref, *, renorm: bool):
    # x_ref/m_ref: (K, T) blocks; w_ref: (K, 1); o_ref: (1, T).
    # out[n] = sum_k w[k] m[k,n] x[k,n]  (/ sum_k w[k] m[k,n] when renorm;
    # coordinates no client covers produce 0 — the caller substitutes its
    # fallback there).
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    m = m_ref[...].astype(jnp.float32)
    wm = w * m                                  # (K, T)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    o_ref[...] = num.astype(o_ref.dtype)


def _masked_mult_kernel(x_ref, w_ref, m_ref, mu_ref, o_ref, *, renorm: bool):
    # The multiplicity-aware coverage pass: per-coordinate client weight
    # w[k] m[k,n] / mu[k,n] (mu = how many union coordinates duplicate the
    # same client coordinate — a duplicated channel's total weight stays
    # w[k]). Same single streaming pass, one extra (K, T) operand; mu <= 0
    # (zero padding) is treated as 1, harmless because m is 0 there too.
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    m = m_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    wm = w * m / jnp.where(mu > 0, mu, 1.0)     # (K, T)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    o_ref[...] = num.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def weighted_sum_2d(x, w, *, block: int = 4096,
                    interpret: Optional[bool] = None):
    """x: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32."""
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1))
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def weighted_sum_masked_2d(x, w, m, *, block: int = 4096,
                           interpret: Optional[bool] = None,
                           renorm: bool = True):
    """x, m: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32.

    Per-coordinate coverage-weighted aggregation: the mask m selects which
    clients own each coordinate, and ``renorm`` divides by the covering
    weight mass ``sum_k w[k] m[k, n]`` (HeteroFL-style renormalization).
    Same blocking as ``weighted_sum_2d`` with the K axis VMEM-resident;
    the mask stream doubles the HBM traffic but the reduction stays
    memory-bound and single-pass.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N), (m.shape, x.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        functools.partial(_masked_kernel, renorm=renorm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1), m)
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def weighted_sum_masked_mult_2d(x, w, m, mu, *, block: int = 4096,
                                interpret: Optional[bool] = None,
                                renorm: bool = True):
    """x, m, mu: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32.

    Multiplicity-aware coverage aggregation: client k's per-coordinate
    weight is ``w[k] m[k,n] / mu[k,n]`` (``mu`` = duplication counts of
    the width embedding), renormalized by the covering mass when
    ``renorm``. Same blocking and single streaming pass as
    ``weighted_sum_masked_2d`` with one more (K, T) operand — still
    memory-bound, every HBM byte touched once.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N) and mu.shape == (K, N), (m.shape, mu.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        functools.partial(_masked_mult_kernel, renorm=renorm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1), m, mu)
    return out[0]
