"""Pallas TPU kernel: weighted multi-model parameter aggregation.

FedAvg's inner loop (paper Eq. 1) is a memory-bound streaming reduction
over K stacked client parameter tensors: out[n] = sum_k w[k] * x[k, n].
The kernel tiles the flattened parameter axis into VMEM-resident blocks
(lane-aligned, 128 multiple) and keeps the K axis resident, so every HBM
byte is touched exactly once (arithmetic intensity ~= 1 FLOP/byte — see
the roofline discussion in EXPERIMENTS.md).

TARGET: TPU (pl.pallas_call + BlockSpec). ``interpret=None`` auto-selects:
compiled (interpret=False) on a TPU backend, interpreter mode elsewhere —
so the same call site is production-fast on TPU and still validated via
interpret=True on CPU against ``ref.weighted_sum_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel(x_ref, w_ref, o_ref):
    # x_ref: (K, T) block; w_ref: (K, 1); o_ref: (1, T)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True).astype(o_ref.dtype)


def _masked_kernel(x_ref, w_ref, m_ref, o_ref, *, renorm: bool):
    # x_ref/m_ref: (K, T) blocks; w_ref: (K, 1); o_ref: (1, T).
    # out[n] = sum_k w[k] m[k,n] x[k,n]  (/ sum_k w[k] m[k,n] when renorm;
    # coordinates no client covers produce 0 — the caller substitutes its
    # fallback there).
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    m = m_ref[...].astype(jnp.float32)
    wm = w * m                                  # (K, T)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    o_ref[...] = num.astype(o_ref.dtype)


def _masked_mult_kernel(x_ref, w_ref, m_ref, mu_ref, o_ref, *, renorm: bool):
    # The multiplicity-aware coverage pass: per-coordinate client weight
    # w[k] m[k,n] / mu[k,n] (mu = how many union coordinates duplicate the
    # same client coordinate — a duplicated channel's total weight stays
    # w[k]). Same single streaming pass, one extra (K, T) operand; mu <= 0
    # (zero padding) is treated as 1, harmless because m is 0 there too.
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    m = m_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    wm = w * m / jnp.where(mu > 0, mu, 1.0)     # (K, T)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    o_ref[...] = num.astype(o_ref.dtype)


def _plane_kernel(*refs, renorm: bool, has_mult: bool, has_fb: bool):
    # The whole-plane fused aggregation pass: x/m[/mu]: (K, T) blocks,
    # w: (K, 1), [fb: (1, T)], o: (1, T). Per coordinate
    #   out = Σ_k (w_k m_k [/ mu_k]) x_k   [ / Σ_k w_k m_k/mu_k  if renorm]
    # and coordinates NO client covers (Σ_k m_k == 0) take fb (or 0) —
    # coverage average, multiplicity division, renormalization and
    # fallback substitution in ONE streaming kernel, so a packed cohort
    # aggregates in a single pallas dispatch instead of one per leaf.
    it = iter(refs)
    x = next(it)[...].astype(jnp.float32)
    w = next(it)[...].astype(jnp.float32)           # (K, 1)
    m = next(it)[...].astype(jnp.float32)
    mu = next(it)[...].astype(jnp.float32) if has_mult else None
    fb = next(it)[...].astype(jnp.float32) if has_fb else None
    o_ref = next(it)
    wm = w * m
    if has_mult:
        # mu <= 0 (zero padding) treated as 1 — harmless, m is 0 there
        wm = wm / jnp.where(mu > 0, mu, 1.0)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    if has_fb:
        covered = jnp.sum(m, axis=0, keepdims=True) > 0
        num = jnp.where(covered, num, fb)
    o_ref[...] = num.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def plane_agg_2d(x, w, m, mu=None, fb=None, *, block: int = 4096,
                 interpret: Optional[bool] = None, renorm: bool = True):
    """x, m [, mu]: (K, N); w: (K,); [fb: (N,)] -> (N,) fp32, N a
    multiple of 128.

    The tiled whole-plane coverage aggregation (``_plane_kernel``): one
    grid over N/block P-tiles, the K axis VMEM-resident, every operand
    streamed from HBM exactly once. ``mu`` (duplication counts) and
    ``fb`` (fallback values for uncovered coordinates) are optional —
    each adds one streamed operand to the SAME single pass.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N), (m.shape, x.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    row = pl.BlockSpec((K, block), lambda i: (0, i))
    ins = [x, w.reshape(K, 1), m]
    specs = [row, pl.BlockSpec((K, 1), lambda i: (0, 0)), row]
    if mu is not None:
        assert mu.shape == (K, N), (mu.shape, x.shape)
        ins.append(mu)
        specs.append(row)
    if fb is not None:
        assert fb.shape == (N,), (fb.shape, x.shape)
        ins.append(fb.reshape(1, N))
        specs.append(pl.BlockSpec((1, block), lambda i: (0, i)))
    out = pl.pallas_call(
        functools.partial(_plane_kernel, renorm=renorm,
                          has_mult=mu is not None, has_fb=fb is not None),
        grid=(N // block,),
        in_specs=specs,
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(*ins)
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def weighted_sum_2d(x, w, *, block: int = 4096,
                    interpret: Optional[bool] = None):
    """x: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32."""
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1))
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def weighted_sum_masked_2d(x, w, m, *, block: int = 4096,
                           interpret: Optional[bool] = None,
                           renorm: bool = True):
    """x, m: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32.

    Per-coordinate coverage-weighted aggregation: the mask m selects which
    clients own each coordinate, and ``renorm`` divides by the covering
    weight mass ``sum_k w[k] m[k, n]`` (HeteroFL-style renormalization).
    Same blocking as ``weighted_sum_2d`` with the K axis VMEM-resident;
    the mask stream doubles the HBM traffic but the reduction stays
    memory-bound and single-pass.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N), (m.shape, x.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        functools.partial(_masked_kernel, renorm=renorm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1), m)
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def weighted_sum_masked_mult_2d(x, w, m, mu, *, block: int = 4096,
                                interpret: Optional[bool] = None,
                                renorm: bool = True):
    """x, m, mu: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32.

    Multiplicity-aware coverage aggregation: client k's per-coordinate
    weight is ``w[k] m[k,n] / mu[k,n]`` (``mu`` = duplication counts of
    the width embedding), renormalized by the covering mass when
    ``renorm``. Same blocking and single streaming pass as
    ``weighted_sum_masked_2d`` with one more (K, T) operand — still
    memory-bound, every HBM byte touched once.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N) and mu.shape == (K, N), (m.shape, mu.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        functools.partial(_masked_mult_kernel, renorm=renorm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1), m, mu)
    return out[0]
