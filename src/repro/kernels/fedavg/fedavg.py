"""Pallas TPU kernel: weighted multi-model parameter aggregation.

FedAvg's inner loop (paper Eq. 1) is a memory-bound streaming reduction
over K stacked client parameter tensors: out[n] = sum_k w[k] * x[k, n].
The kernel tiles the flattened parameter axis into VMEM-resident blocks
(lane-aligned, 128 multiple) and keeps the K axis resident, so every HBM
byte is touched exactly once (arithmetic intensity ~= 1 FLOP/byte — see
the roofline discussion in EXPERIMENTS.md).

TARGET: TPU (pl.pallas_call + BlockSpec). ``interpret=None`` auto-selects:
compiled (interpret=False) on a TPU backend, interpreter mode elsewhere —
so the same call site is production-fast on TPU and still validated via
interpret=True on CPU against ``ref.weighted_sum_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128

# per-core VMEM and the pipeline's double buffering (pallas guide) — the
# budget the auto-selected P-tile must fit; kernels_check validates the
# same numbers statically
VMEM_BUDGET_BYTES = 16 * 2 ** 20
DOUBLE_BUFFER = 2


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def select_block(n: int, k_rows: int, *, row_streams: int,
                 col_streams: int = 1, budget: int = VMEM_BUDGET_BYTES,
                 cap: int = 1 << 16) -> int:
    """Per-backend auto-selected P-tile: the largest lane-multiple block
    whose double-buffered VMEM footprint fits the budget.

    ``row_streams`` counts the ``(K, T)`` operands (plane, masks, mult),
    ``col_streams`` the ``(1, T)`` ones (fallback, output, accumulators) —
    f32 each. The old fixed ``block=4096`` under-tiled small cohorts
    (more grid steps than needed) and could not adapt to large K; this
    picks the tile from the cohort shape instead. ``cap`` bounds the
    tile so interpret-mode tracing stays cheap; an EXPLICIT ``block``
    argument anywhere in ``ops`` still passes through uncapped.
    """
    bytes_per_col = 4 * (row_streams * max(k_rows, 1) + col_streams)
    blk = budget // (DOUBLE_BUFFER * bytes_per_col)
    blk = min(blk, cap)
    if n >= LANE:
        blk = min(blk, -(-n // LANE) * LANE)
    blk = max(LANE, (blk // LANE) * LANE)
    return blk


def _kernel(x_ref, w_ref, o_ref):
    # x_ref: (K, T) block; w_ref: (K, 1); o_ref: (1, T)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True).astype(o_ref.dtype)


def _masked_kernel(x_ref, w_ref, m_ref, o_ref, *, renorm: bool):
    # x_ref/m_ref: (K, T) blocks; w_ref: (K, 1); o_ref: (1, T).
    # out[n] = sum_k w[k] m[k,n] x[k,n]  (/ sum_k w[k] m[k,n] when renorm;
    # coordinates no client covers produce 0 — the caller substitutes its
    # fallback there).
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    m = m_ref[...].astype(jnp.float32)
    wm = w * m                                  # (K, T)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    o_ref[...] = num.astype(o_ref.dtype)


def _masked_mult_kernel(x_ref, w_ref, m_ref, mu_ref, o_ref, *, renorm: bool):
    # The multiplicity-aware coverage pass: per-coordinate client weight
    # w[k] m[k,n] / mu[k,n] (mu = how many union coordinates duplicate the
    # same client coordinate — a duplicated channel's total weight stays
    # w[k]). Same single streaming pass, one extra (K, T) operand; mu <= 0
    # (zero padding) is treated as 1, harmless because m is 0 there too.
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    m = m_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    wm = w * m / jnp.where(mu > 0, mu, 1.0)     # (K, T)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    o_ref[...] = num.astype(o_ref.dtype)


def _plane_kernel(*refs, renorm: bool, has_mult: bool, has_fb: bool):
    # The whole-plane fused aggregation pass: x/m[/mu]: (K, T) blocks,
    # w: (K, 1), [fb: (1, T)], o: (1, T). Per coordinate
    #   out = Σ_k (w_k m_k [/ mu_k]) x_k   [ / Σ_k w_k m_k/mu_k  if renorm]
    # and coordinates NO client covers (Σ_k m_k == 0) take fb (or 0) —
    # coverage average, multiplicity division, renormalization and
    # fallback substitution in ONE streaming kernel, so a packed cohort
    # aggregates in a single pallas dispatch instead of one per leaf.
    it = iter(refs)
    x = next(it)[...].astype(jnp.float32)
    w = next(it)[...].astype(jnp.float32)           # (K, 1)
    m = next(it)[...].astype(jnp.float32)
    mu = next(it)[...].astype(jnp.float32) if has_mult else None
    fb = next(it)[...].astype(jnp.float32) if has_fb else None
    o_ref = next(it)
    wm = w * m
    if has_mult:
        # mu <= 0 (zero padding) treated as 1 — harmless, m is 0 there
        wm = wm / jnp.where(mu > 0, mu, 1.0)
    num = jnp.sum(wm * x, axis=0, keepdims=True)
    if renorm:
        den = jnp.sum(wm, axis=0, keepdims=True)
        num = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    if has_fb:
        covered = jnp.sum(m, axis=0, keepdims=True) > 0
        num = jnp.where(covered, num, fb)
    o_ref[...] = num.astype(o_ref.dtype)


def _accum_kernel(*refs, has_mask: bool, has_mult: bool):
    # The streaming accumulate pass: num/den/cov are (1, T) RUNNING
    # accumulator blocks ALIASED input->output (in-place — the caller
    # donates them), x [, m, mu]: (K_chunk, T) chunk blocks, w: (K, 1).
    # Per coordinate the chunk contributes
    #   num += Σ_k (w_k m_k [/ mu_k]) x_k
    #   den += Σ_k  w_k m_k [/ mu_k]
    #   cov += Σ_k  m_k
    # so after streaming every chunk, ONE finish pass (``_finish_kernel``)
    # reproduces the whole-plane kernel exactly: renorm divides num/den
    # where den > 0, and cov > 0 is the same "some client covers this
    # coordinate" criterion ``_plane_kernel`` reads from Σ m — kept as a
    # separate buffer so the w=0 corner case agrees bit-for-bit.
    it = iter(refs)
    num_in, den_in, cov_in = next(it), next(it), next(it)
    x = next(it)[...].astype(jnp.float32)
    w = next(it)[...].astype(jnp.float32)           # (K, 1)
    m = next(it)[...].astype(jnp.float32) if has_mask else jnp.ones_like(x)
    mu = next(it)[...].astype(jnp.float32) if has_mult else None
    num_o, den_o, cov_o = next(it), next(it), next(it)
    wm = w * m
    if has_mult:
        # mu <= 0 (zero padding) treated as 1 — harmless, m is 0 there
        wm = wm / jnp.where(mu > 0, mu, 1.0)
    num_o[...] = (num_in[...].astype(jnp.float32)
                  + jnp.sum(wm * x, axis=0, keepdims=True)
                  ).astype(num_o.dtype)
    den_o[...] = (den_in[...].astype(jnp.float32)
                  + jnp.sum(wm, axis=0, keepdims=True)).astype(den_o.dtype)
    cov_o[...] = (cov_in[...].astype(jnp.float32)
                  + jnp.sum(m, axis=0, keepdims=True)).astype(cov_o.dtype)


def _accum_q_kernel(*refs, has_mask: bool, has_mult: bool, fold: bool,
                    tile: int):
    # The fused dequantize-accumulate pass (DESIGN.md §10): identical
    # accumulation semantics to ``_accum_kernel``, but x arrives as an
    # int8 block with symmetric per-tile scales and dequantizes IN VMEM
    # — the f32 chunk never exists in HBM.  The scales operand stays
    # whole-array resident ((K, N/tile) f32 — a few KB even for multi-
    # MiB planes; its index map is grid-invariant) and each grid step
    # dynamic-slices its block's tiles.  ``fold`` is filler_mode=
    # "global" fused in: x·m + base·(1−m) before an UNMASKED
    # accumulate, one extra (1, T) stream.
    it = iter(refs)
    num_in, den_in, cov_in = next(it), next(it), next(it)
    xq_ref = next(it)
    s_ref = next(it)
    w = next(it)[...].astype(jnp.float32)           # (K, 1)
    m_ref = next(it) if (has_mask or fold) else None
    mu_ref = next(it) if has_mult else None
    base_ref = next(it) if fold else None
    num_o, den_o, cov_o = next(it), next(it), next(it)
    K, block = xq_ref.shape
    nb = block // tile
    i = pl.program_id(0)
    s = jax.lax.dynamic_slice(s_ref[...], (0, i * nb), (K, nb))
    x = xq_ref[...].astype(jnp.float32).reshape(K, nb, tile)
    x = (x * s[:, :, None]).reshape(K, block)
    if fold:
        mf = m_ref[...].astype(jnp.float32)
        x = x * mf + base_ref[...].astype(jnp.float32) * (1.0 - mf)
        m = jnp.ones_like(x)
    elif has_mask:
        m = m_ref[...].astype(jnp.float32)
    else:
        m = jnp.ones_like(x)
    wm = w * m
    if has_mult:
        mu = mu_ref[...].astype(jnp.float32)
        # mu <= 0 (zero padding) treated as 1 — harmless, m is 0 there
        wm = wm / jnp.where(mu > 0, mu, 1.0)
    num_o[...] = (num_in[...].astype(jnp.float32)
                  + jnp.sum(wm * x, axis=0, keepdims=True)
                  ).astype(num_o.dtype)
    den_o[...] = (den_in[...].astype(jnp.float32)
                  + jnp.sum(wm, axis=0, keepdims=True)).astype(den_o.dtype)
    cov_o[...] = (cov_in[...].astype(jnp.float32)
                  + jnp.sum(m, axis=0, keepdims=True)).astype(cov_o.dtype)


def _finish_kernel(*refs, renorm: bool, has_fb: bool):
    # The one divide pass closing a streamed accumulation: num/den/cov
    # [, fb]: (1, T) blocks -> out (1, T). Same per-coordinate semantics
    # as the tail of ``_plane_kernel``.
    it = iter(refs)
    num = next(it)[...].astype(jnp.float32)
    den = next(it)[...].astype(jnp.float32)
    cov = next(it)[...].astype(jnp.float32)
    fb = next(it)[...].astype(jnp.float32) if has_fb else None
    o_ref = next(it)
    out = num
    if renorm:
        out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    if has_fb:
        out = jnp.where(cov > 0, out, fb)
    o_ref[...] = out.astype(o_ref.dtype)


def plane_accum_2d(num, den, cov, x, w, m=None, mu=None, *,
                   block: int = 4096, interpret: Optional[bool] = None):
    """One streaming accumulate step: num/den/cov ``(1, N)`` f32 running
    buffers (updated IN PLACE via ``input_output_aliases`` — callers
    donate them under jit), x [, m, mu] ``(K_chunk, N)``, w ``(K_chunk,)``,
    N a multiple of 128 and of ``block``. Returns the updated triple.

    The O(P)-memory realization of ``plane_agg_2d``: a cohort streams
    through in ``K_chunk``-row chunks, only the three (N,) accumulators
    and one chunk are ever resident, and ``plane_finish_2d`` closes with
    the single divide/fallback pass. NOT jitted here — ``ops``'s
    accumulator wraps it in a donated jit so the aliasing actually
    updates in place.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert num.shape == den.shape == cov.shape == (1, N), \
        (num.shape, den.shape, cov.shape, x.shape)
    if mu is not None:
        assert m is not None, "mult needs masks"
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    acc = pl.BlockSpec((1, block), lambda i: (0, i))
    row = pl.BlockSpec((K, block), lambda i: (0, i))
    ins = [num, den, cov, x, w.reshape(K, 1)]
    specs = [acc, acc, acc, row, pl.BlockSpec((K, 1), lambda i: (0, 0))]
    if m is not None:
        assert m.shape == (K, N), (m.shape, x.shape)
        ins.append(m)
        specs.append(row)
    if mu is not None:
        assert mu.shape == (K, N), (mu.shape, x.shape)
        ins.append(mu)
        specs.append(row)
    sds = jax.ShapeDtypeStruct((1, N), jnp.float32)
    return pl.pallas_call(
        functools.partial(_accum_kernel, has_mask=m is not None,
                          has_mult=mu is not None),
        grid=(N // block,),
        in_specs=specs,
        out_specs=(acc, acc, acc),
        out_shape=(sds, sds, sds),
        input_output_aliases={0: 0, 1: 1, 2: 2},
        interpret=interpret,
    )(*ins)


def plane_accum_q_2d(num, den, cov, xq, s, w, m=None, mu=None, base=None,
                     *, tile: int = 256, block: int = 4096,
                     interpret: Optional[bool] = None):
    """One fused dequantize-accumulate step: num/den/cov ``(1, N)`` f32
    running buffers (aliased in place — callers donate them under jit),
    xq ``(K_chunk, N)`` int8, s ``(K_chunk, N/tile)`` f32 per-tile
    scales, w ``(K_chunk,)``; optional m/mu ``(K_chunk, N)`` coverage/
    multiplicity rows and ``base`` ``(1, N)`` (filler_mode="global"
    fold: x·m + base·(1−m), then an unmasked accumulate).  N must be a
    multiple of ``block`` and ``block`` of ``tile`` (itself a lane
    multiple).  Same accumulation math as ``plane_accum_2d`` on
    ``dequantize(xq, s)`` — the int8 chunk dequantizes in VMEM, so the
    f32 cohort is never materialized (``core.quant`` + DESIGN.md §10).
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = xq.shape
    assert num.shape == den.shape == cov.shape == (1, N), \
        (num.shape, den.shape, cov.shape, xq.shape)
    assert xq.dtype == jnp.int8, xq.dtype
    if mu is not None:
        assert m is not None, "mult needs masks"
    if base is not None:
        assert m is not None and mu is None, \
            "fold needs masks and is exclusive with mult"
    block = min(block, N)
    assert tile % LANE == 0 and block % tile == 0 and N % block == 0, \
        (N, block, tile)
    assert s.shape == (K, N // tile), (s.shape, (K, N // tile))
    acc = pl.BlockSpec((1, block), lambda i: (0, i))
    row = pl.BlockSpec((K, block), lambda i: (0, i))
    ins = [num, den, cov, xq,
           s, w.reshape(K, 1)]
    specs = [acc, acc, acc, row,
             # scales ride whole-array resident: (K, N/tile) f32 is tiny
             # and the grid-invariant index map keeps the block shape a
             # full-row (lane-exempt) view
             pl.BlockSpec((K, N // tile), lambda i: (0, 0)),
             pl.BlockSpec((K, 1), lambda i: (0, 0))]
    fold = base is not None
    if m is not None:
        assert m.shape == (K, N), (m.shape, xq.shape)
        ins.append(m)
        specs.append(row)
    if mu is not None:
        assert mu.shape == (K, N), (mu.shape, xq.shape)
        ins.append(mu)
        specs.append(row)
    if fold:
        assert base.shape == (1, N), (base.shape, xq.shape)
        ins.append(base)
        specs.append(acc)
    sds = jax.ShapeDtypeStruct((1, N), jnp.float32)
    return pl.pallas_call(
        functools.partial(_accum_q_kernel,
                          has_mask=(m is not None) and not fold,
                          has_mult=mu is not None, fold=fold, tile=tile),
        grid=(N // block,),
        in_specs=specs,
        out_specs=(acc, acc, acc),
        out_shape=(sds, sds, sds),
        input_output_aliases={0: 0, 1: 1, 2: 2},
        interpret=interpret,
    )(*ins)


def plane_finish_2d(num, den, cov, fb=None, *, block: int = 4096,
                    interpret: Optional[bool] = None, renorm: bool = True):
    """The final divide pass of a streamed accumulation: num/den/cov
    [, fb]: ``(1, N)`` -> ``(1, N)`` f32. ``renorm`` divides num by den
    where den > 0; coordinates with cov == 0 (no client ever covered
    them) take ``fb``. Composes with ``plane_accum_2d`` to reproduce
    ``plane_agg_2d`` exactly."""
    if interpret is None:
        interpret = not on_tpu()
    _, N = num.shape
    assert num.shape == den.shape == cov.shape == (1, N)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    acc = pl.BlockSpec((1, block), lambda i: (0, i))
    ins = [num, den, cov]
    specs = [acc, acc, acc]
    if fb is not None:
        assert fb.shape == (1, N), (fb.shape, num.shape)
        ins.append(fb)
        specs.append(acc)
    return pl.pallas_call(
        functools.partial(_finish_kernel, renorm=renorm,
                          has_fb=fb is not None),
        grid=(N // block,),
        in_specs=specs,
        out_specs=acc,
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(*ins)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def plane_agg_2d(x, w, m, mu=None, fb=None, *, block: int = 4096,
                 interpret: Optional[bool] = None, renorm: bool = True):
    """x, m [, mu]: (K, N); w: (K,); [fb: (N,)] -> (N,) fp32, N a
    multiple of 128.

    The tiled whole-plane coverage aggregation (``_plane_kernel``): one
    grid over N/block P-tiles, the K axis VMEM-resident, every operand
    streamed from HBM exactly once. ``mu`` (duplication counts) and
    ``fb`` (fallback values for uncovered coordinates) are optional —
    each adds one streamed operand to the SAME single pass.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N), (m.shape, x.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    row = pl.BlockSpec((K, block), lambda i: (0, i))
    ins = [x, w.reshape(K, 1), m]
    specs = [row, pl.BlockSpec((K, 1), lambda i: (0, 0)), row]
    if mu is not None:
        assert mu.shape == (K, N), (mu.shape, x.shape)
        ins.append(mu)
        specs.append(row)
    if fb is not None:
        assert fb.shape == (N,), (fb.shape, x.shape)
        ins.append(fb.reshape(1, N))
        specs.append(pl.BlockSpec((1, block), lambda i: (0, i)))
    out = pl.pallas_call(
        functools.partial(_plane_kernel, renorm=renorm,
                          has_mult=mu is not None, has_fb=fb is not None),
        grid=(N // block,),
        in_specs=specs,
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(*ins)
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def weighted_sum_2d(x, w, *, block: int = 4096,
                    interpret: Optional[bool] = None):
    """x: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32."""
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1))
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def weighted_sum_masked_2d(x, w, m, *, block: int = 4096,
                           interpret: Optional[bool] = None,
                           renorm: bool = True):
    """x, m: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32.

    Per-coordinate coverage-weighted aggregation: the mask m selects which
    clients own each coordinate, and ``renorm`` divides by the covering
    weight mass ``sum_k w[k] m[k, n]`` (HeteroFL-style renormalization).
    Same blocking as ``weighted_sum_2d`` with the K axis VMEM-resident;
    the mask stream doubles the HBM traffic but the reduction stays
    memory-bound and single-pass.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N), (m.shape, x.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        functools.partial(_masked_kernel, renorm=renorm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1), m)
    return out[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret", "renorm"))
def weighted_sum_masked_mult_2d(x, w, m, mu, *, block: int = 4096,
                                interpret: Optional[bool] = None,
                                renorm: bool = True):
    """x, m, mu: (K, N) with N a multiple of 128; w: (K,) -> (N,) fp32.

    Multiplicity-aware coverage aggregation: client k's per-coordinate
    weight is ``w[k] m[k,n] / mu[k,n]`` (``mu`` = duplication counts of
    the width embedding), renormalized by the covering mass when
    ``renorm``. Same blocking and single streaming pass as
    ``weighted_sum_masked_2d`` with one more (K, T) operand — still
    memory-bound, every HBM byte touched once.
    """
    if interpret is None:
        interpret = not on_tpu()
    K, N = x.shape
    assert m.shape == (K, N) and mu.shape == (K, N), (m.shape, mu.shape)
    block = min(block, N)
    assert N % LANE == 0 and N % block == 0, (N, block)
    grid = (N // block,)
    out = pl.pallas_call(
        functools.partial(_masked_mult_kernel, renorm=renorm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(x, w.reshape(K, 1), m, mu)
    return out[0]
