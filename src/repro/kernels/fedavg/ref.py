"""Pure-jnp oracle for the fedavg aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_sum_ref(x, w):
    """x: (K, N); w: (K,) -> (N,) fp32."""
    return jnp.einsum("k,kn->n", w.astype(jnp.float32),
                      x.astype(jnp.float32))
