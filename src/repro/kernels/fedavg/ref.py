"""Pure-jnp oracles for the fedavg aggregation kernels."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_sum_ref(x, w):
    """x: (K, N); w: (K,) -> (N,) fp32."""
    return jnp.einsum("k,kn->n", w.astype(jnp.float32),
                      x.astype(jnp.float32))


def plane_agg_ref(x, w, *, masks=None, mult=None, fallback=None,
                  renorm: bool = True):
    """x [, masks, mult]: (K, N); w: (K,); [fallback: (N,)] -> (N,) fp32.

    Oracle for the fused whole-plane kernel (``fedavg.plane_agg_2d``):
    coverage-weighted (optionally multiplicity-aware) average with the
    fallback substituted on coordinates no client covers."""
    if masks is None:
        assert mult is None and fallback is None
        return weighted_sum_ref(x, w)
    out = weighted_sum_masked_ref(x, w, masks, mult=mult, renorm=renorm)
    if fallback is not None:
        covered = jnp.sum(masks.astype(jnp.float32), axis=0) > 0
        out = jnp.where(covered, out, fallback.astype(jnp.float32))
    return out


def weighted_sum_masked_ref(x, w, m, *, mult=None, renorm: bool = True):
    """x, m [, mult]: (K, N); w: (K,) -> (N,) fp32 — coverage-weighted
    average; with ``mult`` the per-coordinate client weight is
    ``w_k m_k / mult_k`` (multiplicity-aware)."""
    wm = w.astype(jnp.float32)[:, None] * m.astype(jnp.float32)
    if mult is not None:
        mu = mult.astype(jnp.float32)
        wm = wm / jnp.where(mu > 0, mu, 1.0)
    num = jnp.sum(wm * x.astype(jnp.float32), axis=0)
    if not renorm:
        return num
    den = jnp.sum(wm, axis=0)
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
