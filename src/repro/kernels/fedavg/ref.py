"""Pure-jnp oracles for the fedavg aggregation kernels."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_sum_ref(x, w):
    """x: (K, N); w: (K,) -> (N,) fp32."""
    return jnp.einsum("k,kn->n", w.astype(jnp.float32),
                      x.astype(jnp.float32))


def plane_agg_ref(x, w, *, masks=None, mult=None, fallback=None,
                  renorm: bool = True):
    """x [, masks, mult]: (K, N); w: (K,); [fallback: (N,)] -> (N,) fp32.

    Oracle for the fused whole-plane kernel (``fedavg.plane_agg_2d``):
    coverage-weighted (optionally multiplicity-aware) average with the
    fallback substituted on coordinates no client covers."""
    if masks is None:
        assert mult is None and fallback is None
        return weighted_sum_ref(x, w)
    out = weighted_sum_masked_ref(x, w, masks, mult=mult, renorm=renorm)
    if fallback is not None:
        covered = jnp.sum(masks.astype(jnp.float32), axis=0) > 0
        out = jnp.where(covered, out, fallback.astype(jnp.float32))
    return out


def plane_accum_ref(num, den, cov, x, w, m=None, mu=None):
    """Streaming accumulate oracle: num/den/cov ``(N,)`` (or ``(1, N)``)
    running buffers, x [, m, mu]: ``(K_chunk, N)``, w: ``(K_chunk,)`` ->
    the updated (num, den, cov). One chunk of
    ``fedavg.plane_accum_2d``'s math: num += Σ w·m[/mu]·x,
    den += Σ w·m[/mu], cov += Σ m (m = 1 when absent)."""
    keep = num.ndim == 2
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if m is None and mu is None:
        # unmasked Eq. 1 chunk: one dot instead of (K_chunk, N)
        # temporaries — den/cov updates collapse to scalars
        s = wf @ xf
        kc = jnp.float32(x.shape[0])
        return (num + (s[None] if keep else s),
                den + jnp.sum(wf), cov + kc)
    mf = m.astype(jnp.float32) if m is not None else jnp.ones_like(xf)
    wm = wf[:, None] * mf
    if mu is not None:
        muf = mu.astype(jnp.float32)
        wm = wm / jnp.where(muf > 0, muf, 1.0)
    return (num + jnp.sum(wm * xf, axis=0, keepdims=keep),
            den + jnp.sum(wm, axis=0, keepdims=keep),
            cov + jnp.sum(mf, axis=0, keepdims=keep))


def dequantize_ref(xq, s, *, tile: int = 256):
    """int8 ``(K, N)`` payload + per-tile scales ``(K, ceil(N/tile))``
    -> f32 ``(K, N)``.  Mirrors ``core.quant.dequantize`` (q·scale per
    dense tile; the trailing partial tile reads the same scale)."""
    K, n = xq.shape
    pad = (-n) % tile
    x = xq.astype(jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    x = x.reshape(K, -1, tile) * s.astype(jnp.float32)[:, :, None]
    return x.reshape(K, -1)[:, :n]


def plane_accum_q_ref(num, den, cov, xq, s, w, m=None, mu=None, base=None,
                      *, tile: int = 256):
    """Fused dequantize-accumulate oracle (``fedavg.plane_accum_q_2d``):
    dequantize the int8 chunk, optionally fold the uncovered
    coordinates onto ``base`` (filler_mode="global": x·m + base·(1−m),
    then an UNMASKED accumulate), and run the plain streaming
    accumulate math."""
    x = dequantize_ref(xq, s, tile=tile)
    if base is not None:
        assert m is not None and mu is None, \
            "fold needs masks and is exclusive with mult"
        mf = m.astype(jnp.float32)
        bf = base.astype(jnp.float32).reshape(1, -1)
        x = x * mf + bf * (1.0 - mf)
        m = None
    return plane_accum_ref(num, den, cov, x, w, m, mu)


def plane_finish_ref(num, den, cov, fallback=None, *, renorm: bool = True):
    """The one divide pass closing a streamed accumulation (oracle for
    ``fedavg.plane_finish_2d``): renorm divides num by den where den > 0;
    coordinates no client ever covered (cov == 0) take ``fallback`` —
    exactly the whole-plane kernel's tail, so accumulate-then-finish
    equals ``plane_agg_ref``."""
    out = num.astype(jnp.float32)
    if renorm:
        den = den.astype(jnp.float32)
        out = jnp.where(den > 0, out / jnp.where(den > 0, den, 1.0), 0.0)
    if fallback is not None:
        out = jnp.where(cov > 0, out, fallback.astype(jnp.float32))
    return out


def weighted_sum_masked_ref(x, w, m, *, mult=None, renorm: bool = True):
    """x, m [, mult]: (K, N); w: (K,) -> (N,) fp32 — coverage-weighted
    average; with ``mult`` the per-coordinate client weight is
    ``w_k m_k / mult_k`` (multiplicity-aware)."""
    wm = w.astype(jnp.float32)[:, None] * m.astype(jnp.float32)
    if mult is not None:
        mu = mult.astype(jnp.float32)
        wm = wm / jnp.where(mu > 0, mu, 1.0)
    num = jnp.sum(wm * x.astype(jnp.float32), axis=0)
    if not renorm:
        return num
    den = jnp.sum(wm, axis=0)
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
