"""Jitted public wrappers: aggregate arbitrary-shaped stacked tensors."""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro.kernels.fedavg.fedavg import (LANE, weighted_sum_2d,
                                         weighted_sum_masked_2d,
                                         weighted_sum_masked_mult_2d)


def _flatten_pad(stacked):
    """(K, *shape) -> lane-padded (K, N) plus the original (n, shape)."""
    K = stacked.shape[0]
    shape = stacked.shape[1:]
    n = math.prod(shape) if shape else 1
    flat = stacked.reshape(K, n)
    pad = (-n) % LANE
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, n, shape


def _block_for(n_flat: int, block: int) -> int:
    blk = min(block, n_flat)
    while n_flat % blk:
        blk //= 2
    return max(blk, LANE) if n_flat >= LANE else n_flat


def weighted_sum(stacked, w, *, block: int = 4096,
                 interpret: Optional[bool] = None):
    """stacked: (K, *shape); w: (K,) -> (*shape,) fp32.

    Pads the flattened parameter axis to a lane multiple, runs the Pallas
    kernel, and restores the original shape. ``interpret=None`` compiles
    on TPU and falls back to interpreter mode elsewhere.
    """
    flat, n, shape = _flatten_pad(stacked)
    out = weighted_sum_2d(flat, w, block=_block_for(flat.shape[1], block),
                          interpret=interpret)
    return out[:n].reshape(shape)


def weighted_sum_masked(stacked, w, masks, *, mult=None, block: int = 4096,
                        interpret: Optional[bool] = None,
                        renorm: bool = True):
    """stacked, masks [, mult]: (K, *shape); w: (K,) -> (*shape,) fp32.

    Coverage-weighted aggregation: out = sum_k w_k m_k x_k, divided per
    coordinate by ``sum_k w_k m_k`` when ``renorm`` (coordinates covered
    by no client come back 0 — callers substitute their own fallback).
    With ``mult`` (per-coordinate duplication counts of the width
    embedding) the client weight becomes ``w_k m_k / mult_k`` — the
    multiplicity-aware variant, fused in the same streaming pass. The
    zero padding keeps padded coordinates uncovered, so they slice away
    cleanly (mult's zero padding is neutralized inside the kernel).
    """
    flat, n, shape = _flatten_pad(stacked)
    mflat, _, _ = _flatten_pad(masks)
    blk = _block_for(flat.shape[1], block)
    if mult is None:
        out = weighted_sum_masked_2d(flat, w, mflat, block=blk,
                                     interpret=interpret, renorm=renorm)
    else:
        muflat, _, _ = _flatten_pad(mult)
        out = weighted_sum_masked_mult_2d(flat, w, mflat, muflat, block=blk,
                                          interpret=interpret, renorm=renorm)
    return out[:n].reshape(shape)
