"""Jitted public wrapper: aggregate arbitrary-shaped stacked tensors."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.fedavg import LANE, weighted_sum_2d


def weighted_sum(stacked, w, *, block: int = 4096,
                 interpret: Optional[bool] = None):
    """stacked: (K, *shape); w: (K,) -> (*shape,) fp32.

    Pads the flattened parameter axis to a lane multiple, runs the Pallas
    kernel, and restores the original shape. ``interpret=None`` compiles
    on TPU and falls back to interpreter mode elsewhere.
    """
    K = stacked.shape[0]
    shape = stacked.shape[1:]
    n = int(jnp.prod(jnp.asarray(shape))) if shape else 1
    flat = stacked.reshape(K, n)
    pad = (-n) % LANE
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    blk = min(block, flat.shape[1])
    while flat.shape[1] % blk:
        blk //= 2
    out = weighted_sum_2d(flat, w, block=max(blk, LANE) if flat.shape[1] >= LANE else flat.shape[1],
                          interpret=interpret)
    return out[:n].reshape(shape)
