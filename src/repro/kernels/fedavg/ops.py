"""Jitted public wrappers: aggregate arbitrary-shaped stacked tensors."""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro.kernels.fedavg import ref
from repro.kernels.fedavg.fedavg import (LANE, on_tpu, plane_agg_2d,
                                         weighted_sum_2d,
                                         weighted_sum_masked_2d,
                                         weighted_sum_masked_mult_2d)


def _flatten_pad(stacked):
    """(K, *shape) -> lane-padded (K, N) plus the original (n, shape)."""
    K = stacked.shape[0]
    shape = stacked.shape[1:]
    n = math.prod(shape) if shape else 1
    flat = stacked.reshape(K, n)
    pad = (-n) % LANE
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, n, shape


def _block_for(n_flat: int, block: int) -> int:
    blk = min(block, n_flat)
    while n_flat % blk:
        blk //= 2
    return max(blk, LANE) if n_flat >= LANE else n_flat


def _pad_cols(a, pad: int):
    if not pad:
        return a
    width = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, width)


def plane_agg(plane, w, *, masks=None, mult=None, fallback=None,
              renorm: bool = True, block: int = 4096,
              interpret: Optional[bool] = None,
              use_kernel: Optional[bool] = None):
    """Aggregate a packed ``(K, P)`` parameter plane in ONE pass:
    ``plane_agg(x, w) -> (P,)`` fp32.

    The whole-cohort realization of ``fedavg_stacked``'s math on the
    packed layout (``core.plane``): plain Eq. 1 without ``masks``;
    coverage-weighted with them (renormalized over the covering subset
    when ``renorm``, multiplicity-aware with ``mult``, uncovered
    coordinates substituted from ``fallback``) — masks/mult/fallback are
    row/column-aligned planes, and the entire union model aggregates in
    a single tiled kernel dispatch instead of one per leaf.

    ``use_kernel=None`` auto-selects the Pallas kernel on TPU and the
    jnp oracle (``ref.plane_agg_ref``) elsewhere; the two agree to 1e-6
    (tests/test_plane.py). The parameter axis is zero-padded up to a
    ``block`` multiple so the grid tiles evenly — padded columns are
    uncovered by construction and slice away.
    """
    if mult is not None:
        assert masks is not None, "mult needs masks (coverage aggregation)"
    if fallback is not None:
        assert masks is not None, "fallback needs masks (uncovered coords)"
    if use_kernel is None:
        use_kernel = on_tpu()
    if not use_kernel:
        return ref.plane_agg_ref(plane, w, masks=masks, mult=mult,
                                 fallback=fallback, renorm=renorm)
    K, n = plane.shape
    # lane-round the tile, then zero-pad the plane up to a tile multiple
    # (full-size tiles even when P is lane-odd — no divisor hunting)
    blk = -(-min(block, n) // LANE) * LANE
    pad = (-n) % blk
    x = _pad_cols(plane, pad)
    if masks is None:
        out = weighted_sum_2d(x, w, block=blk, interpret=interpret)
        return out[:n]
    out = plane_agg_2d(
        x, w, _pad_cols(masks, pad),
        _pad_cols(mult, pad) if mult is not None else None,
        _pad_cols(fallback, pad) if fallback is not None else None,
        block=blk, interpret=interpret, renorm=renorm)
    return out[:n]


def weighted_sum(stacked, w, *, block: int = 4096,
                 interpret: Optional[bool] = None):
    """stacked: (K, *shape); w: (K,) -> (*shape,) fp32.

    Pads the flattened parameter axis to a lane multiple, runs the Pallas
    kernel, and restores the original shape. ``interpret=None`` compiles
    on TPU and falls back to interpreter mode elsewhere.
    """
    flat, n, shape = _flatten_pad(stacked)
    out = weighted_sum_2d(flat, w, block=_block_for(flat.shape[1], block),
                          interpret=interpret)
    return out[:n].reshape(shape)


def weighted_sum_masked(stacked, w, masks, *, mult=None, block: int = 4096,
                        interpret: Optional[bool] = None,
                        renorm: bool = True):
    """stacked, masks [, mult]: (K, *shape); w: (K,) -> (*shape,) fp32.

    Coverage-weighted aggregation: out = sum_k w_k m_k x_k, divided per
    coordinate by ``sum_k w_k m_k`` when ``renorm`` (coordinates covered
    by no client come back 0 — callers substitute their own fallback).
    With ``mult`` (per-coordinate duplication counts of the width
    embedding) the client weight becomes ``w_k m_k / mult_k`` — the
    multiplicity-aware variant, fused in the same streaming pass. The
    zero padding keeps padded coordinates uncovered, so they slice away
    cleanly (mult's zero padding is neutralized inside the kernel).
    """
    flat, n, shape = _flatten_pad(stacked)
    mflat, _, _ = _flatten_pad(masks)
    blk = _block_for(flat.shape[1], block)
    if mult is None:
        out = weighted_sum_masked_2d(flat, w, mflat, block=blk,
                                     interpret=interpret, renorm=renorm)
    else:
        muflat, _, _ = _flatten_pad(mult)
        out = weighted_sum_masked_mult_2d(flat, w, mflat, muflat, block=blk,
                                          interpret=interpret, renorm=renorm)
    return out[:n].reshape(shape)
