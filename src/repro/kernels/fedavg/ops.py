"""Jitted public wrappers: aggregate arbitrary-shaped stacked tensors."""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fedavg import ref
from repro.kernels.fedavg.fedavg import (LANE, on_tpu, plane_accum_2d,
                                         plane_accum_q_2d, plane_agg_2d,
                                         plane_finish_2d, select_block,
                                         weighted_sum_2d,
                                         weighted_sum_masked_2d,
                                         weighted_sum_masked_mult_2d)


def _flatten_pad(stacked):
    """(K, *shape) -> lane-padded (K, N) plus the original (n, shape)."""
    K = stacked.shape[0]
    shape = stacked.shape[1:]
    n = math.prod(shape) if shape else 1
    flat = stacked.reshape(K, n)
    pad = (-n) % LANE
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, n, shape


def _block_for(n_flat: int, block: int) -> int:
    blk = min(block, n_flat)
    while n_flat % blk:
        blk //= 2
    return max(blk, LANE) if n_flat >= LANE else n_flat


def _pad_cols(a, pad: int):
    if not pad:
        return a
    width = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, width)


# the jnp oracle as ONE jitted program (CPU/GPU hot path): the eager
# call used to build ~6 full (K, P) temporaries per aggregation — jit
# fuses them and was the plane layout's missing CPU win (BENCH_new.json
# showed plane losing to the tree path exactly on this path)
_plane_agg_ref_jit = jax.jit(
    lambda plane, w, masks, mult, fallback, renorm: ref.plane_agg_ref(
        plane, w, masks=masks, mult=mult, fallback=fallback, renorm=renorm),
    static_argnums=(5,))


def plane_agg(plane, w, *, masks=None, mult=None, fallback=None,
              renorm: bool = True, block: Optional[int] = None,
              interpret: Optional[bool] = None,
              use_kernel: Optional[bool] = None):
    """Aggregate a packed ``(K, P)`` parameter plane in ONE pass:
    ``plane_agg(x, w) -> (P,)`` fp32.

    The whole-cohort realization of ``fedavg_stacked``'s math on the
    packed layout (``core.plane``): plain Eq. 1 without ``masks``;
    coverage-weighted with them (renormalized over the covering subset
    when ``renorm``, multiplicity-aware with ``mult``, uncovered
    coordinates substituted from ``fallback``) — masks/mult/fallback are
    row/column-aligned planes, and the entire union model aggregates in
    a single tiled kernel dispatch instead of one per leaf.

    ``use_kernel=None`` auto-selects the Pallas kernel on TPU and the
    jnp oracle (``ref.plane_agg_ref``, as ONE jitted program) elsewhere;
    the two agree to 1e-6 (tests/test_plane.py). The parameter axis is
    zero-padded up to a ``block`` multiple so the grid tiles evenly —
    padded columns are uncovered by construction and slice away.
    ``block=None`` auto-selects the P-tile from the cohort shape and the
    VMEM budget (``fedavg.select_block``); an explicit int passes
    through lane-rounded but otherwise verbatim.
    """
    if mult is not None:
        assert masks is not None, "mult needs masks (coverage aggregation)"
    if fallback is not None:
        assert masks is not None, "fallback needs masks (uncovered coords)"
    if use_kernel is None:
        use_kernel = on_tpu()
    if not use_kernel:
        return _plane_agg_ref_jit(plane, w, masks, mult, fallback, renorm)
    K, n = plane.shape
    if block is None:
        rows = 1 + (masks is not None) + (mult is not None)
        block = select_block(n, K, row_streams=rows,
                             col_streams=1 + (fallback is not None))
    # lane-round the tile, then zero-pad the plane up to a tile multiple
    # (full-size tiles even when P is lane-odd — no divisor hunting)
    blk = -(-min(block, n) // LANE) * LANE
    pad = (-n) % blk
    x = _pad_cols(plane, pad)
    if masks is None:
        out = weighted_sum_2d(x, w, block=blk, interpret=interpret)
        return out[:n]
    out = plane_agg_2d(
        x, w, _pad_cols(masks, pad),
        _pad_cols(mult, pad) if mult is not None else None,
        _pad_cols(fallback, pad) if fallback is not None else None,
        block=blk, interpret=interpret, renorm=renorm)
    return out[:n]


def weighted_sum(stacked, w, *, block: int = 4096,
                 interpret: Optional[bool] = None):
    """stacked: (K, *shape); w: (K,) -> (*shape,) fp32.

    Pads the flattened parameter axis to a lane multiple, runs the Pallas
    kernel, and restores the original shape. ``interpret=None`` compiles
    on TPU and falls back to interpreter mode elsewhere.
    """
    flat, n, shape = _flatten_pad(stacked)
    out = weighted_sum_2d(flat, w, block=_block_for(flat.shape[1], block),
                          interpret=interpret)
    return out[:n].reshape(shape)


def weighted_sum_masked(stacked, w, masks, *, mult=None, block: int = 4096,
                        interpret: Optional[bool] = None,
                        renorm: bool = True):
    """stacked, masks [, mult]: (K, *shape); w: (K,) -> (*shape,) fp32.

    Coverage-weighted aggregation: out = sum_k w_k m_k x_k, divided per
    coordinate by ``sum_k w_k m_k`` when ``renorm`` (coordinates covered
    by no client come back 0 — callers substitute their own fallback).
    With ``mult`` (per-coordinate duplication counts of the width
    embedding) the client weight becomes ``w_k m_k / mult_k`` — the
    multiplicity-aware variant, fused in the same streaming pass. The
    zero padding keeps padded coordinates uncovered, so they slice away
    cleanly (mult's zero padding is neutralized inside the kernel).
    """
    flat, n, shape = _flatten_pad(stacked)
    mflat, _, _ = _flatten_pad(masks)
    blk = _block_for(flat.shape[1], block)
    if mult is None:
        out = weighted_sum_masked_2d(flat, w, mflat, block=blk,
                                     interpret=interpret, renorm=renorm)
    else:
        muflat, _, _ = _flatten_pad(mult)
        out = weighted_sum_masked_mult_2d(flat, w, mflat, muflat, block=blk,
                                          interpret=interpret, renorm=renorm)
    return out[:n].reshape(shape)


# ------------------------------------------------- streaming accumulation
@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=("block", "interpret", "use_kernel"))
def _accum_step(num, den, cov, x, w, m, mu, *, block: int,
                interpret: Optional[bool], use_kernel: bool):
    """One donated accumulate step on PADDED ``(1, N)`` buffers — the
    Pallas streaming kernel (aliased in-place) on TPU, the jnp oracle
    (fused by this jit, buffers still donated) elsewhere."""
    if use_kernel:
        return plane_accum_2d(num, den, cov, x, w, m, mu, block=block,
                              interpret=interpret)
    return ref.plane_accum_ref(num, den, cov, x, w, m, mu)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=("tile", "block", "interpret",
                                    "use_kernel"))
def _accum_q_step(num, den, cov, xq, s, w, m, mu, base, *, tile: int,
                  block: int, interpret: Optional[bool], use_kernel: bool):
    """One donated fused dequantize-accumulate step on PADDED ``(1, N)``
    buffers — the Pallas kernel (aliased in-place) on TPU, the jnp
    oracle (fused by this jit, buffers still donated) elsewhere."""
    if use_kernel:
        return plane_accum_q_2d(num, den, cov, xq, s, w, m, mu, base,
                                tile=tile, block=block, interpret=interpret)
    return ref.plane_accum_q_ref(num, den, cov, xq, s, w, m, mu, base,
                                 tile=tile)


@functools.partial(jax.jit, static_argnames=("n", "renorm", "block",
                                             "interpret", "use_kernel"))
def _accum_finish(num, den, cov, fb, *, n: int, renorm: bool, block: int,
                  interpret: Optional[bool], use_kernel: bool):
    """The final divide pass on padded buffers, sliced back to ``(n,)``."""
    if fb is not None:
        fb = _pad_cols(fb.astype(jnp.float32), num.shape[1] - fb.shape[0]
                       ).reshape(1, -1)
    if use_kernel:
        out = plane_finish_2d(num, den, cov, fb, block=block,
                              interpret=interpret, renorm=renorm)[0]
    else:
        out = ref.plane_finish_ref(num[0], den[0], cov[0],
                                   None if fb is None else fb[0],
                                   renorm=renorm)
    return out[:n]


def plane_accum(num, den, cov, chunk, w, *, masks=None, mult=None,
                block: Optional[int] = None,
                interpret: Optional[bool] = None,
                use_kernel: Optional[bool] = None):
    """Functional streaming accumulate on UNPADDED ``(n,)`` buffers:
    ``(num, den, cov) + (K_chunk, n) chunk -> updated (num, den, cov)``.

    The stateless face of :class:`PlaneAccumulator` (which keeps its
    buffers padded and donated across chunks — prefer it in loops; this
    wrapper pads and slices per call). ``use_kernel=None`` auto-selects
    the Pallas kernel on TPU, the jnp oracle elsewhere; the two agree to
    1e-6. The analysis gate traces THIS surface
    (``analysis/kernels_check.py``)."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if mult is not None:
        assert masks is not None, "mult needs masks (coverage aggregation)"
    K, n = chunk.shape
    assert num.shape == den.shape == cov.shape == (n,), \
        (num.shape, den.shape, cov.shape, chunk.shape)
    if not use_kernel:
        return ref.plane_accum_ref(num, den, cov, chunk, w, masks, mult)
    if block is None:
        rows = 1 + (masks is not None) + (mult is not None)
        block = select_block(n, K, row_streams=rows, col_streams=6)
    blk = -(-min(block, max(n, LANE)) // LANE) * LANE
    pad = (-n) % blk
    trip = plane_accum_2d(
        _pad_cols(num, pad).reshape(1, -1),
        _pad_cols(den, pad).reshape(1, -1),
        _pad_cols(cov, pad).reshape(1, -1),
        _pad_cols(chunk, pad), w,
        _pad_cols(masks, pad) if masks is not None else None,
        _pad_cols(mult, pad) if mult is not None else None,
        block=blk, interpret=interpret)
    return tuple(t[0, :n] for t in trip)


def plane_accum_q(num, den, cov, chunk, scales, w, *, masks=None,
                  mult=None, base=None, tile: int = 256,
                  block: Optional[int] = None,
                  interpret: Optional[bool] = None,
                  use_kernel: Optional[bool] = None):
    """Functional fused dequantize-accumulate on UNPADDED ``(n,)``
    buffers: ``(num, den, cov) + int8 (K_chunk, n) chunk with per-tile
    scales (K_chunk, ceil(n/tile)) -> updated (num, den, cov)``.

    The compressed-wire twin of :func:`plane_accum` (``core.quant``
    encodes, this accumulates — the f32 chunk never materializes):
    ``masks``/``mult`` are the coverage variants, ``base`` ``(n,)`` is
    the filler_mode="global" fold (x·m + base·(1−m), then an unmasked
    accumulate).  ``use_kernel=None`` auto-selects the Pallas kernel on
    TPU, the jnp oracle elsewhere; the two agree to 1e-6 after
    dequantization.  The analysis gate traces THIS surface
    (``analysis/kernels_check.py``)."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if mult is not None:
        assert masks is not None, "mult needs masks (coverage aggregation)"
    if base is not None:
        assert masks is not None and mult is None, \
            "fold needs masks and is exclusive with mult"
    K, n = chunk.shape
    assert num.shape == den.shape == cov.shape == (n,), \
        (num.shape, den.shape, cov.shape, chunk.shape)
    assert tile % LANE == 0, tile
    nt = -(-n // tile)
    assert scales.shape == (K, nt), (scales.shape, (K, nt))
    if not use_kernel:
        return ref.plane_accum_q_ref(num, den, cov, chunk, scales, w,
                                     masks, mult,
                                     None if base is None
                                     else base.reshape(1, -1), tile=tile)
    if block is None:
        rows = 1 + (masks is not None) + (mult is not None)
        block = select_block(n, K, row_streams=rows,
                             col_streams=6 + (base is not None))
    # tile-round the block so the grid tiles the scale grid evenly, then
    # zero-pad everything to a block multiple (padded tiles: scale 0,
    # payload 0 — they contribute nothing and slice away)
    blk = -(-min(block, max(n, tile)) // tile) * tile
    pad = (-n) % blk
    N = n + pad
    trip = plane_accum_q_2d(
        _pad_cols(num, pad).reshape(1, -1),
        _pad_cols(den, pad).reshape(1, -1),
        _pad_cols(cov, pad).reshape(1, -1),
        _pad_cols(chunk, pad),
        _pad_cols(jnp.asarray(scales, jnp.float32), N // tile - nt),
        w,
        _pad_cols(masks, pad) if masks is not None else None,
        _pad_cols(mult, pad) if mult is not None else None,
        (_pad_cols(base, pad).reshape(1, -1)
         if base is not None else None),
        tile=tile, block=blk, interpret=interpret)
    return tuple(t[0, :n] for t in trip)


def plane_finish(num, den, cov, *, fallback=None, renorm: bool = True,
                 block: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 use_kernel: Optional[bool] = None):
    """Close a streamed accumulation on UNPADDED ``(n,)`` buffers ->
    ``(n,)`` f32 — renorm divide where den > 0, ``fallback`` where no
    client ever covered (cov == 0). ``plane_accum`` chunks + this equal
    ``plane_agg`` on the whole plane to 1e-6."""
    if use_kernel is None:
        use_kernel = on_tpu()
    n = num.shape[0]
    assert num.shape == den.shape == cov.shape == (n,)
    if not use_kernel:
        return ref.plane_finish_ref(num, den, cov, fallback, renorm=renorm)
    if block is None:
        block = select_block(n, 1, row_streams=0, col_streams=5)
    blk = -(-min(block, max(n, LANE)) // LANE) * LANE
    pad = (-n) % blk
    out = plane_finish_2d(
        _pad_cols(num, pad).reshape(1, -1),
        _pad_cols(den, pad).reshape(1, -1),
        _pad_cols(cov, pad).reshape(1, -1),
        (_pad_cols(fallback, pad).reshape(1, -1)
         if fallback is not None else None),
        block=blk, interpret=interpret, renorm=renorm)
    return out[0, :n]


class PlaneAccumulator:
    """Streaming O(P)-memory plane aggregation state (DESIGN.md §9).

    Holds three running ``(P,)`` buffers — numerator, renorm denominator
    and coverage count — and consumes a cohort in ``(K_chunk, P)`` row
    chunks: ``update`` is ONE donated jitted step per chunk (the Pallas
    streaming kernel with in-place aliasing on TPU, the fused jnp oracle
    elsewhere), so aggregation memory is the three buffers plus one
    chunk, independent of the cohort size K. ``finish`` closes with the
    single divide/fallback pass and reproduces ``plane_agg`` on the
    whole plane to 1e-6.

    Hierarchical (two-level) aggregation composes for free: edge
    reducers each stream their sub-cohort into their own accumulator,
    ``merge`` sums the partial triples (exact — the masked weighted sum
    is associative), and the global reducer finishes once.

    ``stats()`` reports the donated-buffer accounting the memory
    envelope test asserts on: ``buffer_bytes`` (the three padded
    buffers) and ``peak_bytes`` (buffers + the largest chunk's streamed
    operands) — O(P·K_chunk), never O(P·K).
    """

    def __init__(self, n: int, *, block: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 use_kernel: Optional[bool] = None, k_hint: int = 16,
                 q_tile: Optional[int] = None):
        self.n = int(n)
        self.use_kernel = on_tpu() if use_kernel is None else bool(use_kernel)
        self.interpret = interpret
        # the fused dequantize path (``update_q``) needs the padded width
        # to tile the scale grid evenly — set ``q_tile`` (a lane multiple,
        # ``core.quant``'s tile) to round the block up to a tile multiple
        self.q_tile = None
        if q_tile is not None:
            assert q_tile >= LANE and q_tile % LANE == 0, q_tile
            self.q_tile = int(q_tile)
        if block is None:
            # the VMEM-budgeted tile only matters on the kernel path;
            # the jnp oracle just wants minimal column padding
            block = (select_block(self.n, k_hint, row_streams=3,
                                  col_streams=6)
                     if self.use_kernel else LANE)
        unit = self.q_tile or LANE
        self.block = -(-min(block, max(self.n, unit)) // unit) * unit
        self._pad = (-self.n) % self.block
        shape = (1, self.n + self._pad)
        self._num = jnp.zeros(shape, jnp.float32)
        self._den = jnp.zeros(shape, jnp.float32)
        self._cov = jnp.zeros(shape, jnp.float32)
        self.rows = 0
        self.chunks = 0
        self.peak_rows = 0
        self._chunk_bytes = 0

    def _note(self, kc: int, nbytes: int):
        self.rows += int(kc)
        self.chunks += 1
        self.peak_rows = max(self.peak_rows, int(kc))
        self._chunk_bytes = max(self._chunk_bytes, int(nbytes))

    def update(self, chunk, w, *, masks=None, mult=None):
        """Accumulate one ``(K_chunk, n)`` row chunk with weights ``w``
        (``(K_chunk,)`` — already renormalized over the FULL cohort by
        the caller; chunking must not change the weights).  The chunk's
        float dtype is preserved into the kernel (bf16 wire chunks
        stream at 2 bytes/coordinate — the kernels cast to f32 in VMEM);
        everything else is taken as f32."""
        if mult is not None:
            assert masks is not None, "mult needs masks"
        kc, n = chunk.shape
        assert n == self.n, (n, self.n)
        x = jnp.asarray(chunk)
        if x.dtype not in (jnp.bfloat16, jnp.float16, jnp.float32):
            x = x.astype(jnp.float32)
        x = _pad_cols(x, self._pad)
        m = (_pad_cols(jnp.asarray(masks, jnp.float32), self._pad)
             if masks is not None else None)
        mu = (_pad_cols(jnp.asarray(mult, jnp.float32), self._pad)
              if mult is not None else None)
        self._num, self._den, self._cov = _accum_step(
            self._num, self._den, self._cov, x,
            jnp.asarray(w, jnp.float32), m, mu,
            block=self.block, interpret=self.interpret,
            use_kernel=self.use_kernel)
        n_pad = self.n + self._pad
        self._note(kc, kc * n_pad * (x.dtype.itemsize
                                     + 4 * (m is not None)
                                     + 4 * (mu is not None)))
        return self

    def update_q(self, chunk, scales, w, *, masks=None, mult=None,
                 base=None):
        """Accumulate one int8 ``(K_chunk, n)`` chunk with per-tile
        ``scales`` (``(K_chunk, ceil(n/q_tile))``) through the FUSED
        dequantize-accumulate kernel — the f32 chunk never exists;
        aggregation traffic is 1 byte/coordinate plus the scale grid.
        ``base`` ``(n,)`` is the filler_mode="global" fold.  Needs
        ``q_tile`` set at construction (the padded width must tile the
        scale grid evenly)."""
        assert self.q_tile is not None, \
            "update_q needs q_tile set at construction"
        if mult is not None:
            assert masks is not None, "mult needs masks"
        if base is not None:
            assert masks is not None and mult is None, \
                "fold needs masks and is exclusive with mult"
        kc, n = chunk.shape
        assert n == self.n, (n, self.n)
        tile = self.q_tile
        n_pad = self.n + self._pad
        nt = -(-n // tile)
        assert scales.shape == (kc, nt), (scales.shape, (kc, nt))
        xq = _pad_cols(jnp.asarray(chunk, jnp.int8), self._pad)
        s = _pad_cols(jnp.asarray(scales, jnp.float32), n_pad // tile - nt)
        m = (_pad_cols(jnp.asarray(masks, jnp.float32), self._pad)
             if masks is not None else None)
        mu = (_pad_cols(jnp.asarray(mult, jnp.float32), self._pad)
              if mult is not None else None)
        b = (_pad_cols(jnp.asarray(base, jnp.float32), self._pad
                       ).reshape(1, -1) if base is not None else None)
        self._num, self._den, self._cov = _accum_q_step(
            self._num, self._den, self._cov, xq, s,
            jnp.asarray(w, jnp.float32), m, mu, b,
            tile=tile, block=self.block, interpret=self.interpret,
            use_kernel=self.use_kernel)
        self._note(kc, kc * (n_pad + 4 * (n_pad // tile)
                             + 4 * n_pad * (m is not None)
                             + 4 * n_pad * (mu is not None))
                   + 4 * n_pad * (b is not None))
        return self

    def merge(self, other: "PlaneAccumulator"):
        """Global reduce of the two-level hierarchy: sum another edge
        reducer's partial triple into this one (exact by associativity).
        Layouts must match (same n and padded block)."""
        assert other.n == self.n and other._num.shape == self._num.shape, \
            "merge needs accumulators over the same plane layout"
        self._num = self._num + other._num
        self._den = self._den + other._den
        self._cov = self._cov + other._cov
        self.rows += other.rows
        self.chunks += other.chunks
        self.peak_rows = max(self.peak_rows, other.peak_rows)
        self._chunk_bytes = max(self._chunk_bytes, other._chunk_bytes)
        return self

    def partials(self):
        """The raw (num, den, cov) triple, unpadded ``(n,)`` each — what
        an edge reducer ships to the global reduce."""
        return (self._num[0, :self.n], self._den[0, :self.n],
                self._cov[0, :self.n])

    def finish(self, *, renorm: bool = True, fallback=None):
        """The one divide pass -> ``(n,)`` f32. ``renorm`` divides by the
        accumulated covering mass where positive; ``fallback``
        substitutes on coordinates no streamed client covered."""
        fb = (jnp.asarray(fallback, jnp.float32)
              if fallback is not None else None)
        return _accum_finish(self._num, self._den, self._cov, fb,
                             n=self.n, renorm=renorm, block=self.block,
                             interpret=self.interpret,
                             use_kernel=self.use_kernel)

    def stats(self) -> dict:
        """Donated-buffer accounting: the accumulation's memory envelope
        is ``buffer_bytes`` (3 padded f32 buffers) + the largest chunk's
        streamed operands (actual itemsizes — an int8 wire chunk counts
        1 byte/coordinate plus its scale grid) — O(P·K_chunk),
        independent of total rows."""
        n_pad = self.n + self._pad
        buffers = 3 * n_pad * 4
        return {"n": self.n, "padded": n_pad, "block": self.block,
                "rows": self.rows, "chunks": self.chunks,
                "peak_chunk_rows": self.peak_rows,
                "buffer_bytes": buffers, "chunk_bytes": self._chunk_bytes,
                "peak_bytes": buffers + self._chunk_bytes}
