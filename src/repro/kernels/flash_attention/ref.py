"""jnp reference for the flash-attention kernels — fallback AND oracle.

Mirrors ``models.attention.blockwise_attention`` semantics exactly
(scale on q, position masks with -1 = masked key, fp32 accumulation,
``acc / max(l, 1e-30)`` normalisation) but is vectorised over the whole
query axis: no ``lax.map`` over q blocks, so it is the faster XLA path
off-TPU, and it additionally returns the log-sum-exp residual that the
hand-written backward consumes.

Layout is the kernel layout: q ``(B, KV, G, Sq, hd)``; k, v
``(B, Sk, KV, hd)``; q_pos ``(Sq,)`` / kv_pos ``(Sk,)`` int32 absolute
positions. Sequences longer than one kv block stream through a
``lax.scan`` so peak memory stays O(Sq * block_kv) per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(q_pos, kv_pos, causal: bool, window: int):
    """(Sq, Sk) bool mask from absolute positions (-1 = masked key)."""
    valid = jnp.broadcast_to((kv_pos >= 0)[None, :],
                             (q_pos.shape[0], kv_pos.shape[0]))
    if causal:
        valid = valid & (q_pos[:, None] >= kv_pos[None, :])
    if window > 0:
        valid = valid & (q_pos[:, None] - kv_pos[None, :] < window)
    return valid


def _attend_block(qf, kb, vb, qpos, kpos, causal, window, m, l, acc):
    """One online-softmax step. qf (B,KV,G,Sq,hd) pre-scaled f32;
    kb/vb (B,bk,KV,hd); carry m/l (B,KV,G,Sq), acc (B,KV,G,Sq,hd)."""
    s = jnp.einsum("bkgqd,bskd->bkgqs", qf, kb.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    mask = _block_mask(qpos, kpos, causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    acc = acc * corr[..., None] + pv
    return m_new, l, acc


def flash_fwd_ref(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                  block_kv=128):
    """Returns (out, lse): out (B,KV,G,Sq,hd) f32, lse (B,KV,G,Sq) f32
    with lse = rowmax + log(rowsum) of the masked scores."""
    B, KV, G, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    if Sk <= block_kv:
        m, l, acc = _attend_block(qf, k, v, q_pos, kv_pos, causal, window,
                                  m0, l0, a0)
    else:
        assert Sk % block_kv == 0, (Sk, block_kv)
        nk, bk = Sk // block_kv, block_kv
        kbs = k.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
        vbs = v.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
        kps = kv_pos.reshape(nk, bk)

        def body(carry, xs):
            kb, vb, kpi = xs
            return _attend_block(qf, kb, vb, q_pos, kpi, causal, window,
                                 *carry), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kbs, vbs, kps))
    lmax = jnp.maximum(l, 1e-30)
    return acc / lmax[..., None], m + jnp.log(lmax)


def _bwd_block(qf, kb, vb, qpos, kpos, causal, window, lse, delta, do):
    """Per-kv-block backward. Returns (dq_partial (B,KV,G,Sq,hd),
    dk_block, dv_block (B,bk,KV,hd)) — all f32."""
    s = jnp.einsum("bkgqd,bskd->bkgqs", qf, kb.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    mask = _block_mask(qpos, kpos, causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - lse[..., None])          # normalized probs, 0 off-mask
    dv = jnp.einsum("bkgqs,bkgqd->bskd", p, do,
                    preferred_element_type=jnp.float32)
    dp = jnp.einsum("bkgqd,bskd->bkgqs", do, vb.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    ds = p * (dp - delta[..., None])
    dq = jnp.einsum("bkgqs,bskd->bkgqd", ds, kb.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    dk = jnp.einsum("bkgqs,bkgqd->bskd", ds, qf,
                    preferred_element_type=jnp.float32)
    return dq, dk, dv


def flash_bwd_ref(q, k, v, q_pos, kv_pos, out, lse, dout, *, causal=True,
                  window=0, block_kv=128):
    """Recompute-from-residuals backward. Returns (dq, dk, dv) f32 in the
    primal layouts. ``delta = rowsum(dout * out)`` is the FlashAttention-2
    normalizer correction; dk absorbs the q scale because s = (q*scale)k^T."""
    B, KV, G, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    do = dout.astype(jnp.float32)
    delta = (do * out).sum(axis=-1)          # (B,KV,G,Sq)
    if Sk <= block_kv:
        dq, dk, dv = _bwd_block(qf, k, v, q_pos, kv_pos, causal, window,
                                lse, delta, do)
        return dq * scale, dk, dv
    assert Sk % block_kv == 0, (Sk, block_kv)
    nk, bk = Sk // block_kv, block_kv
    kbs = k.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
    vbs = v.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
    kps = kv_pos.reshape(nk, bk)

    def body(dq_acc, xs):
        kb, vb, kpi = xs
        dq, dk, dv = _bwd_block(qf, kb, vb, q_pos, kpi, causal, window,
                                lse, delta, do)
        return dq_acc + dq, (dk, dv)

    dq, (dks, dvs) = jax.lax.scan(
        body, jnp.zeros((B, KV, G, Sq, hd), jnp.float32), (kbs, vbs, kps))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, hd)
    return dq * scale, dk, dv
