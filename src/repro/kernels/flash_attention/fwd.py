"""Pallas TPU kernel: flash-attention FORWARD for local training.

Grid (b, kv_head, q_block, kv_block): the innermost grid dim streams kv
blocks through VMEM with an online-softmax accumulator in scratch
(m/l/acc persist across the sequential innermost dimension — TPU grid
semantics), so VMEM stays O(block_q * block_kv) per head group and the
full (Sq, Sk) score matrix never materializes.

Masking is position-based (same contract as ``models.attention``): the
caller passes absolute positions per q/kv row, -1 marks a padded key, so
causal + sliding-window + padding all reduce to one mask. Alongside the
output the kernel writes the log-sum-exp residual ``lse = m + log(l)``
that the backward kernels use to recompute attention probabilities.

TARGET: TPU. Validated via interpret=True against ``ref.flash_fwd_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
            m_ref, l_ref, acc_ref, *, causal: bool, window: int, n_kv: int):
    r = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (G, bq, hd)
    G, bq, hd = q.shape
    k = k_ref[0, :, 0].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    bk = k.shape[0]
    qpos = qpos_ref[0]                                    # (bq,)
    kpos = kpos_ref[0]                                    # (bk,)

    mask = jnp.broadcast_to((kpos >= 0)[None, :], (bq, bk))
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window > 0:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)

    scale = hd ** -0.5
    s = jax.lax.dot_general(q.reshape(G * bq, hd) * scale, k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(G, bq, bk)
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_ref[...]                                   # (G, bq, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    pv = jax.lax.dot_general(p.reshape(G * bq, bk), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(G, bq, hd)
    m_ref[...] = m_new

    @pl.when(r == n_kv - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l_safe))[..., 0]


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_fwd(q, k, v, q_pos, kv_pos, *, causal: bool = True,
              window: int = 0, block_q: int = 128, block_kv: int = 128,
              interpret: bool = True):
    """q: (B, KV, G, Sq, hd); k, v: (B, Sk, KV, hd); q_pos (Sq,) /
    kv_pos (Sk,) int32 absolute positions (-1 = masked key). Sq/Sk must
    divide by the blocks. Returns (out (B,KV,G,Sq,hd) f32,
    lse (B,KV,G,Sq) f32)."""
    B, KV, G, Sq, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_kv, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    return pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window, n_kv=nk),
        grid=(B, KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, qi, r: (0, qi)),
            pl.BlockSpec((1, bk), lambda b, h, qi, r: (0, r)),
            pl.BlockSpec((1, 1, G, bq, hd),
                         lambda b, h, qi, r: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, r: (b, r, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, r: (b, r, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, bq, hd),
                         lambda b, h, qi, r: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, qi, r: (b, h, 0, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, Sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, bq, 1), jnp.float32),     # running row max
            pltpu.VMEM((G, bq, 1), jnp.float32),     # running normalizer
            pltpu.VMEM((G, bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q_pos.reshape(1, Sq), kv_pos.reshape(1, Sk), q, k, v)
