"""Training-grade flash attention: tiled Pallas fwd/bwd + custom_vjp.

``ops.flash_attention`` is a drop-in for
``repro.models.attention.blockwise_attention`` (same signature, same
masking semantics, bit-compatible outputs within f32 rounding) with a
hand-written backward pass that recomputes the attention probabilities
from saved log-sum-exp residuals instead of differentiating through the
online-softmax scan.
"""
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["flash_attention"]
