"""Dispatch + custom_vjp for flash attention — blockwise_attention drop-in.

Auto-select follows the fedavg contract: ``use_kernel=None`` resolves to
the compiled Pallas kernels on TPU and the vectorised jnp reference
elsewhere; ``interpret=None`` means compiled on TPU, interpreter off-TPU
(only reachable when the kernel is forced on for validation).

The custom_vjp core operates on the kernel layout q (B,KV,G,S,hd) with
block-padded sequences; padding/transposition/slicing live OUTSIDE the
custom_vjp so JAX differentiates them natively. Positions are integer
primals, so the backward returns float0 cotangents for them.

Block sizes are capped at ``BLOCK_CAP`` (=128): the backward keeps
q/do/dq blocks plus a (G, bq, bk) probability tile resident per grid
cell, and 128x128 holds that under the x2-buffered VMEM budget even at
G=16 (glm4-9b's 32q/2kv grouping).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fedavg.fedavg import on_tpu
from repro.kernels.flash_attention import bwd as _bwd
from repro.kernels.flash_attention import fwd as _fwd
from repro.kernels.flash_attention import ref as _ref

BLOCK_CAP = 128


def _float0(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _run_fwd(q, k, v, q_pos, kv_pos, causal, window, bq, bk, use_kernel,
             interpret):
    if use_kernel:
        return _fwd.flash_fwd(q, k, v, q_pos, kv_pos, causal=causal,
                              window=window, block_q=bq, block_kv=bk,
                              interpret=interpret)
    return _ref.flash_fwd_ref(q, k, v, q_pos, kv_pos, causal=causal,
                              window=window, block_kv=bk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def _flash(q, k, v, q_pos, kv_pos, causal, window, bq, bk, use_kernel,
           interpret):
    out, _ = _run_fwd(q, k, v, q_pos, kv_pos, causal, window, bq, bk,
                      use_kernel, interpret)
    return out


def _flash_fwd_rule(q, k, v, q_pos, kv_pos, causal, window, bq, bk,
                    use_kernel, interpret):
    out, lse = _run_fwd(q, k, v, q_pos, kv_pos, causal, window, bq, bk,
                        use_kernel, interpret)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd_rule(causal, window, bq, bk, use_kernel, interpret, res,
                    dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    if use_kernel:
        do = dout.astype(jnp.float32)
        delta = (do * out).sum(axis=-1)
        dq, dk, dv = _bwd.flash_bwd(q, k, v, q_pos, kv_pos, lse, delta, do,
                                    causal=causal, window=window, block_q=bq,
                                    block_kv=bk, interpret=interpret)
    else:
        dq, dk, dv = _ref.flash_bwd_ref(q, k, v, q_pos, kv_pos, out, lse,
                                        dout, causal=causal, window=window,
                                        block_kv=bk)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            _float0(q_pos), _float0(kv_pos))


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _pad_to(x, size, axis, value=0):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def flash_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                    block_q=512, block_kv=512, use_kernel=None,
                    interpret=None):
    """Flash attention with a hand-written backward. Same contract as
    ``models.attention.blockwise_attention``: q (B,Sq,KV,G,hd);
    k, v (B,Sk,KV,hd); q_pos (Sq,) / kv_pos (Sk,) absolute positions
    (-1 = masked key). Returns (B,Sq,KV*G,hd) in q.dtype."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    bq = max(1, min(block_q, BLOCK_CAP, Sq))
    bk = max(1, min(block_kv, BLOCK_CAP, Sk))
    nq, nk = -(-Sq // bq), -(-Sk // bk)

    qt = _pad_to(q, nq * bq, 1).transpose(0, 2, 3, 1, 4)   # (B,KV,G,Sq',hd)
    kp = _pad_to(k, nk * bk, 1)
    vp = _pad_to(v, nk * bk, 1)
    qpos_p = _pad_to(q_pos.astype(jnp.int32), nq * bq, 0, value=-1)
    kpos_p = _pad_to(kv_pos.astype(jnp.int32), nk * bk, 0, value=-1)

    out = _flash(qt, kp, vp, qpos_p, kpos_p, causal, window, bq, bk,
                 bool(use_kernel), bool(interpret))
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, nq * bq, KV * G, hd)
    return out[:, :Sq].astype(q.dtype)
