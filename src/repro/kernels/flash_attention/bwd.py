"""Pallas TPU kernels: flash-attention BACKWARD (dQ and dK/dV).

Two kernels, both recomputing the attention probabilities from the
forward's log-sum-exp residual (``p = exp(s - lse)``) instead of storing
the (Sq, Sk) score matrix:

  * dQ   — grid (b, kv_head, q_block, kv_block): each q block streams the
           kv blocks, accumulating ``dq += ds @ k`` in VMEM scratch.
  * dK/dV — grid (b, kv_head, kv_block, q_block): each kv block streams
           the q blocks, accumulating ``dk += ds^T @ (q*scale)`` and
           ``dv += p^T @ do`` (summed over the G query heads of the
           group) in VMEM scratch.

``delta = rowsum(dout * out)`` (the FlashAttention-2 softmax correction)
is precomputed by the caller — it is a cheap elementwise reduction.

TARGET: TPU. Validated via interpret=True against ``ref.flash_bwd_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _probs(q, k, qpos, kpos, lse, *, causal: bool, window: int):
    """Recompute normalized attention probs p (G,bq,bk) and the masked
    scaled scores' ingredients. q (G,bq,hd) f32 pre-scaled; k (bk,hd)."""
    G, bq, hd = q.shape
    bk = k.shape[0]
    mask = jnp.broadcast_to((kpos >= 0)[None, :], (bq, bk))
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window > 0:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    s = jax.lax.dot_general(q.reshape(G * bq, hd), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = jnp.where(mask[None], s.reshape(G, bq, bk), NEG_INF)
    return jnp.exp(s - lse[..., None])


def _dq_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, lse_ref, delta_ref,
               do_ref, dq_ref, acc_ref, *, causal: bool, window: int,
               n_kv: int):
    r = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (G, bq, hd)
    G, bq, hd = q.shape
    scale = hd ** -0.5
    k = k_ref[0, :, 0].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    bk = k.shape[0]
    do = do_ref[0, 0].astype(jnp.float32)                 # (G, bq, hd)

    p = _probs(q * scale, k, qpos_ref[0], kpos_ref[0], lse_ref[0, 0],
               causal=causal, window=window)
    dp = jax.lax.dot_general(do.reshape(G * bq, hd), v,
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp.reshape(G, bq, bk) - delta_ref[0, 0][..., None])
    dq = jax.lax.dot_general(ds.reshape(G * bq, bk), k,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] + dq.reshape(G, bq, hd) * scale

    @pl.when(r == n_kv - 1)
    def _finish():
        dq_ref[0, 0] = acc_ref[...]


def _dkv_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, lse_ref, delta_ref,
                do_ref, dk_ref, dv_ref, dk_acc, dv_acc, *, causal: bool,
                window: int, n_q: int):
    r = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)                   # (G, bq, hd)
    G, bq, hd = q.shape
    scale = hd ** -0.5
    qf = q * scale
    k = k_ref[0, :, 0].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    bk = k.shape[0]
    do = do_ref[0, 0].astype(jnp.float32)                 # (G, bq, hd)

    p = _probs(qf, k, qpos_ref[0], kpos_ref[0], lse_ref[0, 0],
               causal=causal, window=window)
    # dv += p^T @ do, dk += ds^T @ qf — contract over (G, bq) jointly
    dv = jax.lax.dot_general(p.reshape(G * bq, bk), do.reshape(G * bq, hd),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do.reshape(G * bq, hd), v,
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp.reshape(G, bq, bk) - delta_ref[0, 0][..., None])
    dk = jax.lax.dot_general(ds.reshape(G * bq, bk), qf.reshape(G * bq, hd),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dk_acc[...] = dk_acc[...] + dk
    dv_acc[...] = dv_acc[...] + dv

    @pl.when(r == n_q - 1)
    def _finish():
        dk_ref[0, :, 0] = dk_acc[...]
        dv_ref[0, :, 0] = dv_acc[...]


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_bwd(q, k, v, q_pos, kv_pos, lse, delta, dout, *,
              causal: bool = True, window: int = 0, block_q: int = 128,
              block_kv: int = 128, interpret: bool = True):
    """Inputs in the forward's layouts; lse/delta (B,KV,G,Sq) f32;
    dout (B,KV,G,Sq,hd). Returns (dq (B,KV,G,Sq,hd), dk, dv (B,Sk,KV,hd)),
    all f32."""
    B, KV, G, Sq, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_kv, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    qp2, kp2 = q_pos.reshape(1, Sq), kv_pos.reshape(1, Sk)

    q_spec = pl.BlockSpec((1, 1, G, bq, hd),
                          lambda b, h, i, r: (b, h, 0, i, 0))
    q_spec_t = pl.BlockSpec((1, 1, G, bq, hd),
                            lambda b, h, i, r: (b, h, 0, r, 0))
    kv_spec = pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, r: (b, r, h, 0))
    kv_spec_t = pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, r: (b, i, h, 0))
    row_spec = pl.BlockSpec((1, 1, G, bq), lambda b, h, i, r: (b, h, 0, i))
    row_spec_t = pl.BlockSpec((1, 1, G, bq), lambda b, h, i, r: (b, h, 0, r))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, window=window, n_kv=nk),
        grid=(B, KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, i, r: (0, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, r: (0, r)),
            q_spec, kv_spec, kv_spec, row_spec, row_spec, q_spec,
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Sq, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((G, bq, hd), jnp.float32)],
        interpret=interpret,
    )(qp2, kp2, q, k, v, lse, delta, dout)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, window=window, n_q=nq),
        grid=(B, KV, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, i, r: (0, r)),
            pl.BlockSpec((1, bk), lambda b, h, i, r: (0, i)),
            q_spec_t, kv_spec_t, kv_spec_t, row_spec_t, row_spec_t, q_spec_t,
        ],
        out_specs=[
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, r: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, r: (b, i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sk, KV, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Sk, KV, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, hd), jnp.float32),       # dk accumulator
            pltpu.VMEM((bk, hd), jnp.float32),       # dv accumulator
        ],
        interpret=interpret,
    )(qp2, kp2, q, k, v, lse, delta, dout)
    return dq, dk, dv
