"""Pallas TPU kernel: flash decode attention vs a (ring) KV cache, with
causal + sliding-window masking — the long_500k serving hot path.

One grid cell = (batch b, kv head h, kv-sequence tile s). The G = H/KV
query heads of the group stay VMEM-resident; the kv tiles stream through
VMEM with an online-softmax accumulator in scratch (m/l/acc persist across
the sequential innermost grid dimension — TPU grid semantics). Masking is
position-based, so ring-buffer caches (slot = pos % W) work unchanged: the
caller passes each slot's absolute position.

TARGET: TPU. Validated via interpret=True against ``ref.decode_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, block_s: int,
            n_steps: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                # (Ts, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)                # (Ts, hd)
    kpos = kpos_ref[0]                                    # (Ts,)
    qpos = qpos_ref[0, 0]

    scale = q.shape[-1] ** -0.5
    scores = jnp.dot(q * scale, k.T,
                     preferred_element_type=jnp.float32)  # (G, Ts)
    valid = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        valid = valid & (qpos - kpos < window)
    scores = jnp.where(valid[None, :], scores, NEG_INF)

    m_prev = m_ref[...]                                   # (G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_steps - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "block_s", "interpret"))
def swa_decode(q, k, v, key_pos, q_pos, *, window: int = 0,
               block_s: int = 512, interpret: bool = True):
    """q: (B, KV, G, hd); k, v: (B, S, KV, hd); key_pos: (S,) int32 absolute
    slot positions (-1 = unwritten); q_pos: scalar int32.
    Returns (B, KV, G, hd) fp32."""
    B, KV, G, hd = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_steps = S // bs
    grid = (B, KV, n_steps)
    qpos_arr = jnp.full((1, 1), q_pos, jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, window=window, block_s=bs,
                          n_steps=n_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (0, 0)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (0, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),      # m
            pltpu.VMEM((G, 1), jnp.float32),      # l
            pltpu.VMEM((G, hd), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qpos_arr, q, k, v, key_pos.reshape(1, S))
