"""Pure-jnp oracle for the sliding-window flash decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def prefill_ref(q, k, v, *, window: int, causal: bool = True):
    """q: (B,KV,G,S,hd); k,v: (B,S,KV,hd) -> (B,KV,G,S,hd) fp32."""
    hd = q.shape[-1]
    S = q.shape[3]
    s = jnp.einsum("bkgqd,bskd->bkgqs", q.astype(jnp.float32) * hd ** -0.5,
                   k.astype(jnp.float32))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))


def decode_ref(q, k, v, key_pos, q_pos, *, window: int = 0):
    """q: (B, KV, G, hd); k, v: (B, S, KV, hd) -> (B, KV, G, hd) fp32."""
    hd = q.shape[-1]
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32) * hd ** -0.5,
                   k.astype(jnp.float32))
    valid = (key_pos >= 0) & (key_pos <= q_pos)
    if window > 0:
        valid = valid & (q_pos - key_pos < window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
