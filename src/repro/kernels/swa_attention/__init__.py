from repro.kernels.swa_attention import ops, ref  # noqa: F401
