"""Pallas TPU kernel: banded (sliding-window) flash attention for PREFILL.

Grid (b, kv_head, q_block, rel_kv_block): each q block of size bq visits
only the ~(window+bq)/bk kv blocks inside its band — the innermost grid
dim streams them with an online-softmax accumulator in VMEM scratch, so
HBM traffic is O(S * window / bk) instead of O(S^2).

The kv block index is clamped at the sequence edges; the kernel recomputes
the unclamped index and masks fully out-of-range blocks so clamping never
double-counts a block.

TARGET: TPU. Validated via interpret=True against ``ref.prefill_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kv_start_block(qi, window, bq, bk):
    # first kv block of q-block qi's band (may be negative; clamped later)
    return (qi * bq - (window - 1)) // bk


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            window: int, bq: int, bk: int, n_kv_blocks: int, n_rel: int,
            causal: bool):
    qi = pl.program_id(2)
    r = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    first = _kv_start_block(qi, window, bq, bk) if window > 0 else 0
    nominal = first + r
    in_range = (nominal >= 0) & (nominal <= (qi * bq + bq - 1) // bk)

    q = q_ref[0, 0].astype(jnp.float32)                   # (G*bq? no: G,bq,hd)
    G, bq_, hd = q.shape
    k = k_ref[0, :, 0].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    clamped = jnp.clip(nominal, 0, n_kv_blocks - 1)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq_, bk), 0)
    kpos = clamped * bk + jax.lax.broadcasted_iota(jnp.int32, (bq_, bk), 1)
    mask = jnp.broadcast_to(in_range, (bq_, bk))
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)

    scale = hd ** -0.5
    s = jax.lax.dot_general(q.reshape(G * bq_, hd) * scale, k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(G, bq_, bk)
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_ref[...]                                   # (G, bq, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    pv = jax.lax.dot_general(p.reshape(G * bq_, bk), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(G, bq_, hd)
    m_ref[...] = m_new

    @pl.when(r == n_rel - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_kv",
                                             "causal", "interpret"))
def swa_prefill(q, k, v, *, window: int, block_q: int = 256,
                block_kv: int = 256, causal: bool = True,
                interpret: bool = True):
    """q: (B, KV, G, S, hd); k, v: (B, S, KV, hd). Returns (B,KV,G,S,hd)
    fp32. S must divide by the blocks; window > 0."""
    B, KV, G, S, hd = q.shape
    bq, bk = min(block_q, S), min(block_kv, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    span = (window - 1) + bq if window > 0 else S
    n_rel = -(-span // bk) + 1 if window > 0 else nk

    def kv_index(b, h, qi, r):
        if window > 0:
            first = _kv_start_block(qi, window, bq, bk)
            return (b, jnp.clip(first + r, 0, nk - 1), h, 0)
        return (b, jnp.clip(r, 0, nk - 1), h, 0)

    return pl.pallas_call(
        functools.partial(_kernel, window=window, bq=bq, bk=bk,
                          n_kv_blocks=nk, n_rel=n_rel, causal=causal),
        grid=(B, KV, nq, n_rel),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, hd), lambda b, h, qi, r: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, hd),
                               lambda b, h, qi, r: (b, h, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, S, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, bq, 1), jnp.float32),
            pltpu.VMEM((G, bq, 1), jnp.float32),
            pltpu.VMEM((G, bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
