"""Jitted wrapper matching the model-side decode_attention signature."""
from __future__ import annotations

from typing import Optional

from repro.kernels.fedavg.fedavg import on_tpu
from repro.kernels.swa_attention.decode import swa_decode


def decode_attention(q, k_cache, v_cache, key_pos, q_pos, *, window: int = 0,
                     block_s: int = 512, interpret: Optional[bool] = None):
    """q: (B, H, hd); caches: (B, S, KV, hd); key_pos: (S,) -> (B, H, hd).

    ``interpret=None`` auto-selects per the fedavg contract: compiled on
    TPU, interpreter elsewhere (CPU Pallas execution is interpret-only).
    """
    if interpret is None:
        interpret = not on_tpu()
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, hd)
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    out = swa_decode(qr, k_cache, v_cache, key_pos, q_pos, window=window,
                     block_s=max(bs, 1), interpret=bool(interpret))
    return out.reshape(B, H, hd)
