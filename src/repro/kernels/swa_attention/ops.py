"""Jitted wrapper matching the model-side decode_attention signature."""
from __future__ import annotations


from repro.kernels.swa_attention.decode import swa_decode


def decode_attention(q, k_cache, v_cache, key_pos, q_pos, *, window: int = 0,
                     block_s: int = 512, interpret: bool = True):
    """q: (B, H, hd); caches: (B, S, KV, hd); key_pos: (S,) -> (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, hd)
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    out = swa_decode(qr, k_cache, v_cache, key_pos, q_pos, window=window,
                     block_s=max(bs, 1), interpret=interpret)
    return out.reshape(B, H, hd)
