"""Jitted wrapper: NetChange To-Wider on arbitrary matrices.

``widen_in`` (duplicate columns, scale=1) and ``widen_out`` (duplicate +
1/|group| split) both reduce to one kernel call with different scales.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.netchange.widen import widen_2d

BLK = 256


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w)


def widen_cols(x, mapping, *, split: bool = False, interpret: bool = True):
    """x: (R, old) -> (R, new). split=False duplicates (To-Wider incoming);
    split=True divides each duplicate group by its size (outgoing)."""
    mapping = np.asarray(mapping, np.int32)
    old = x.shape[1]
    if split:
        counts = np.bincount(mapping, minlength=old)
        scale = (1.0 / counts[mapping]).astype(np.float32)
    else:
        scale = np.ones(mapping.shape, np.float32)
    new = mapping.shape[0]
    xp = _pad_to(x, BLK, 1)
    xp = _pad_to(xp, BLK, 0)
    # pad the mapping with pointers to a real (zero-padded) column
    mp = np.concatenate([mapping, np.zeros(((-new) % BLK,), np.int32)])
    sp = np.concatenate([scale, np.zeros(((-new) % BLK,), np.float32)])
    out = widen_2d(xp, jnp.asarray(mp), jnp.asarray(sp), interpret=interpret)
    return out[: x.shape[0], :new]
