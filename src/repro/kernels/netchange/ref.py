"""Pure-jnp oracle for the To-Wider expansion kernel."""
from __future__ import annotations

import jax.numpy as jnp


def widen_ref(x, mapping, scale):
    """x: (R, old); mapping/scale: (new,) -> (R, new) fp32."""
    return (jnp.take(x.astype(jnp.float32), mapping, axis=1)
            * scale.astype(jnp.float32)[None, :])
