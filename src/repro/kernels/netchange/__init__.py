from repro.kernels.netchange import ops, ref  # noqa: F401
