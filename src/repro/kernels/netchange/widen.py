"""Pallas TPU kernel: NetChange To-Wider column expansion (Alg. 2).

GPU intuition would implement out[r, j] = x[r, map[j]] * scale[j] as a
gather; TPU adaptation (DESIGN.md §3): build the scaled one-hot selection
block on the fly from an iota/compare and feed the MXU with a blocked
matmul  out = x @ Sel,  Sel[i, j] = scale[j] * [map[j] == i].
This turns a lane-hostile gather into systolic matmuls with perfect
VMEM tiling.

Grid: (rows/Tr, new/Tn, old/To), accumulation over the old axis (innermost,
sequential on TPU) into the revisited output block.

TARGET: TPU. Validated via interpret=True against ``ref.widen_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(x_ref, map_ref, scale_ref, o_ref, *, block_old: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                        # (Tr, To)
    m = map_ref[...]                                          # (1, Tn)
    s = scale_ref[...].astype(jnp.float32)                    # (1, Tn)
    base = k * block_old
    iota = base + jax.lax.broadcasted_iota(jnp.int32, (block_old, m.shape[1]), 0)
    sel = jnp.where(iota == m, s, 0.0)                        # (To, Tn)
    o_ref[...] += jnp.dot(x, sel, preferred_element_type=jnp.float32
                          ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "block_new", "block_old",
                                    "interpret"))
def widen_2d(x, mapping, scale, *, block_rows: int = 256,
             block_new: int = 256, block_old: int = 256,
             interpret: bool = True):
    """x: (R, old); mapping/scale: (new,) -> (R, new) fp32.

    All dims must be multiples of the respective blocks (ops.py pads)."""
    R, old = x.shape
    new = mapping.shape[0]
    br, bn, bo = min(block_rows, R), min(block_new, new), min(block_old, old)
    assert R % br == 0 and new % bn == 0 and old % bo == 0
    grid = (R // br, new // bn, old // bo)
    return pl.pallas_call(
        functools.partial(_kernel, block_old=bo),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bo), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, new), jnp.float32),
        interpret=interpret,
    )(x, mapping.reshape(1, -1), scale.reshape(1, -1))
