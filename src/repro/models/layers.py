"""Primitive layers shared by every architecture family."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                         # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def mlp_init(key, cfg, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    bias = cfg.mlp_bias
    if cfg.mlp_kind == "gelu":
        p = {"wi": dense_init(ks[0], (d_model, d_ff), dtype),
             "wd": dense_init(ks[1], (d_ff, d_model), dtype)}
        if bias:
            p["bi"] = jnp.zeros((d_ff,), dtype)
            p["bd"] = jnp.zeros((d_model,), dtype)
        return p
    p = {"wg": dense_init(ks[0], (d_model, d_ff), dtype),
         "wu": dense_init(ks[1], (d_model, d_ff), dtype),
         "wd": dense_init(ks[2], (d_ff, d_model), dtype)}
    return p


def mlp_apply(p, x, mlp_kind: str, ctx=None):
    if mlp_kind == "gelu":
        h = x @ p["wi"]
        if "bi" in p:
            h = h + p["bi"]
        h = gelu(h)
        out = tp_row_matmul(h, p["wd"], ctx)
        if "bd" in p:
            out = out + p["bd"]
        return out
    act = gelu if mlp_kind == "geglu" else jax.nn.silu
    return tp_row_matmul(act(x @ p["wg"]) * (x @ p["wu"]), p["wd"], ctx)


def tp_row_matmul(h, w, ctx=None):
    """Row-parallel projection  y = h @ w  with the contraction dim sharded
    over the model axis (attention wo, MLP wd). With ``ctx.tp_bf16_reduce``
    the partial sums are cast to the activation dtype BEFORE the psum —
    XLA's default emits an f32 all-reduce + convert (2x collective bytes;
    verified in EXPERIMENTS.md §Perf glm4 iteration 4)."""
    if ctx is None or not (getattr(ctx, "distributed", False)
                           and ctx.tp_bf16_reduce):
        return h @ w
    K = h.shape[-1]
    m = ctx.model_size
    if K % m or w.shape[0] != K:
        return h @ w
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    ma = ctx.model_axis
    dp = ctx.data_axes if ctx.data_axes else None
    lead = (dp,) + (None,) * (h.ndim - 2)
    hspec = P(*lead, ma)
    ospec = P(*lead, None)

    def local(hl, wl):
        return jax.lax.psum((hl @ wl).astype(h.dtype), ma)

    return shard_map(local, mesh=ctx.mesh, in_specs=(hspec, P(ma, None)),
                     out_specs=ospec, check_rep=False)(h, w)


def causal_conv1d(x, kernel, state=None):
    """Depthwise causal conv along time. x: (B, S, C), kernel: (W, C).

    Returns (out, new_state) where state is the last W-1 inputs (B, W-1, C).
    """
    W = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)              # (B, S+W-1, C)
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1], :] * kernel[i]
    new_state = xp[:, -(W - 1):, :] if W > 1 else state
    return out, new_state
