"""Mixture-of-Experts FFN with sort-based (MegaBlocks-style, TPU-adapted)
dispatch.

Distribution strategy (see DESIGN.md §5): experts are sharded over the
``model`` mesh axis; activations enter replicated over ``model`` and
sharded over the data axes. Each device computes the contribution of its
local experts to its local tokens and the results are combined with a
``psum`` over ``model`` ("EP with replicated activations"). An optional
all-to-all dispatch variant (``ctx.moe_all_to_all``) is a §Perf knob.

The identical math runs single-device (CPU smoke tests) when no mesh is
present.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, mlp_apply, mlp_init
from repro.sharding.ctx import CPU_CTX, ShardCtx


def moe_init(key, cfg, dtype):
    m = cfg.moe
    D, E, Fe = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), dtype),
        # router bias: zero at init; NetChange expert duplication shifts the
        # duplicates by -log(group size) here (a logit shift cannot be
        # expressed in the weight matrix).
        "router_b": jnp.zeros((E,), dtype),
        "wg": dense_init(ks[1], (E, D, Fe), dtype, fan_in=D),
        "wu": dense_init(ks[2], (E, D, Fe), dtype, fan_in=D),
        "wd": dense_init(ks[3], (E, Fe, D), dtype, fan_in=Fe),
    }
    if m.n_shared:
        # shared experts: one fused MLP of width n_shared * d_ff_shared
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, mlp_kind="swiglu")
        p["shared"] = mlp_init(ks[4], shared_cfg, D,
                               m.n_shared * m.d_ff_shared, dtype)
    return p


def _route(router, x2d, top_k, router_b=None):
    logits = (x2d @ router).astype(jnp.float32)               # (N,E)
    if router_b is not None:
        logits = logits + router_b.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    wts, ids = jax.lax.top_k(probs, top_k)                    # (N,k)
    wts = wts / jnp.maximum(wts.sum(-1, keepdims=True), 1e-9)
    return wts, ids, probs


def _capacity(n_tokens, top_k, n_experts_total, cf):
    return max(1, int(n_tokens * top_k / n_experts_total * cf) + 1)


def _dispatch_ffn_combine(x2d, ids, wts, wg, wu, wd, *, e_offset, n_experts_total,
                          capacity):
    """Sort-based dispatch -> per-expert matmuls -> weighted combine.

    x2d (N,D); ids/wts (N,k); wg/wu/wd local expert stacks (E_loc, ...).
    Tokens routed to experts outside [e_offset, e_offset+E_loc) contribute 0.
    """
    N, D = x2d.shape
    k = ids.shape[1]
    E_loc = wg.shape[0]
    C = capacity

    flat_ids = ids.reshape(-1) - e_offset                     # (N*k,)
    in_range = (flat_ids >= 0) & (flat_ids < E_loc)
    sort_key = jnp.where(in_range, flat_ids, E_loc)
    order = jnp.argsort(sort_key)                             # stable
    sid = sort_key[order]
    tok = order // k

    counts = jnp.bincount(sid, length=E_loc + 1)[:E_loc]
    starts = jnp.cumsum(counts) - counts                      # exclusive cumsum
    rank = jnp.arange(N * k) - starts[jnp.clip(sid, 0, E_loc - 1)]
    keep = (sid < E_loc) & (rank >= 0) & (rank < C)

    dest_e = jnp.where(keep, sid, 0)
    dest_c = jnp.where(keep, rank, C)                         # overflow row C
    buf = jnp.zeros((E_loc, C + 1, D), x2d.dtype)
    buf = buf.at[dest_e, dest_c].set(x2d[tok] * keep[:, None].astype(x2d.dtype))
    buf = buf[:, :C]                                          # (E_loc,C,D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wu)
    y_buf = jnp.einsum("ecf,efd->ecd", h, wd)                 # (E_loc,C,D)

    gath = y_buf[dest_e, jnp.minimum(dest_c, C - 1)]          # (N*k,D)
    gate = wts.reshape(-1)[order]
    contrib = gath * (gate * keep).astype(gath.dtype)[:, None]
    out = jnp.zeros((N, D), x2d.dtype).at[tok].add(contrib)
    return out


def _moe_routed(x, p, cfg, *, e_offset=0, axis_name=None):
    """Routed-experts part. x: (B,S,D) local shard; expert stacks local."""
    m = cfg.moe
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    wts, ids, _ = _route(p["router"], x2d, m.top_k, p.get("router_b"))
    C = _capacity(x2d.shape[0], m.top_k, m.n_experts, m.capacity_factor)
    out = _dispatch_ffn_combine(x2d, ids, wts, p["wg"], p["wu"], p["wd"],
                                e_offset=e_offset, n_experts_total=m.n_experts,
                                capacity=C)
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)
    return out.reshape(B, S, D)


def moe_apply(p, cfg, x, ctx: ShardCtx = CPU_CTX):
    """x: (B,S,D) global. Dispatch + expert FFN + combine (+ shared experts)."""
    m = cfg.moe
    if not ctx.distributed or m.n_experts % ctx.model_size:
        # single device, or fewer experts than model shards: keep experts
        # whole and let XLA tensor-parallelize d_ff_expert (rules.py shards
        # wg/wu/wd over `model` on the F axis in that regime).
        out = _moe_routed(x, p, cfg)
    else:
        mesh = ctx.mesh
        ma = ctx.model_axis
        da = ctx.data_axes if ctx.data_axes else None
        E = m.n_experts
        msize = mesh.shape[ma]

        def local_fn(x_l, router, router_b, wg, wu, wd):
            e_off = jax.lax.axis_index(ma) * (E // msize)
            p_l = {"router": router, "router_b": router_b,
                   "wg": wg, "wu": wu, "wd": wd}
            return _moe_routed(x_l, p_l, cfg, e_offset=e_off, axis_name=ma)

        x_spec = P(da, None, None)
        from jax.experimental.shard_map import shard_map
        fn = shard_map(local_fn, mesh=mesh,
                       in_specs=(x_spec, P(), P(), P(ma), P(ma), P(ma)),
                       out_specs=x_spec, check_rep=False)
        out = fn(x, p["router"], p["router_b"], p["wg"], p["wu"], p["wd"])
    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, "swiglu")
    return out
