"""Model registry: uniform handles over the transformer substrate.

Besides the per-architecture :class:`Model` handle, the registry is the
ENUMERABLE surface for static tooling (``repro.analysis``): ``arch_ids()``
lists every architecture, ``Model.param_shapes()`` gives the abstract
parameter tree (``jax.eval_shape`` — no allocation), and ``plane_spec()``
its packed-plane layout, so contract checkers can sweep the whole matrix
without ever materializing a model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax

from repro import configs
from repro.configs import ModelConfig, get_config
from repro.core.plane import PlaneSpec
from repro.models import transformer as T
from repro.sharding.ctx import CPU_CTX, ShardCtx


def arch_ids() -> Tuple[str, ...]:
    """Every registered architecture id, in registry order."""
    return tuple(configs.ARCH_IDS)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key):
        return T.init_params(key, self.cfg)

    def param_shapes(self):
        """Abstract parameter tree (ShapeDtypeStructs) — eval_shape of
        ``init``, no FLOPs, no device memory."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def forward(self, params, tokens, *, ctx: ShardCtx = CPU_CTX, aux=None):
        return T.forward(params, self.cfg, tokens, ctx=ctx, aux=aux)

    def prefill(self, params, tokens, *, ctx: ShardCtx = CPU_CTX, aux=None,
                cache_len=None):
        return T.prefill(params, self.cfg, tokens, ctx=ctx, aux=aux,
                         cache_len=cache_len)

    def decode_step(self, params, token, cache, pos, *, ctx: ShardCtx = CPU_CTX):
        return T.decode_step(params, self.cfg, token, cache, pos, ctx=ctx)

    def init_cache(self, B, S_max, dtype=None):
        return T.init_cache(self.cfg, B, S_max, dtype)


def get_model(arch_or_cfg) -> Model:
    cfg = arch_or_cfg if isinstance(arch_or_cfg, ModelConfig) else get_config(arch_or_cfg)
    return Model(cfg)


def plane_spec(arch_or_cfg) -> PlaneSpec:
    """Packed-plane layout of an architecture's parameter tree, derived
    abstractly (hashable; usable as a static jit argument)."""
    return PlaneSpec.from_tree(get_model(arch_or_cfg).param_shapes())
