"""Model registry: uniform handles over the transformer substrate."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_config
from repro.models import transformer as T
from repro.sharding.ctx import CPU_CTX, ShardCtx


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key):
        return T.init_params(key, self.cfg)

    def forward(self, params, tokens, *, ctx: ShardCtx = CPU_CTX, aux=None):
        return T.forward(params, self.cfg, tokens, ctx=ctx, aux=aux)

    def prefill(self, params, tokens, *, ctx: ShardCtx = CPU_CTX, aux=None,
                cache_len=None):
        return T.prefill(params, self.cfg, tokens, ctx=ctx, aux=aux,
                         cache_len=cache_len)

    def decode_step(self, params, token, cache, pos, *, ctx: ShardCtx = CPU_CTX):
        return T.decode_step(params, self.cfg, token, cache, pos, ctx=ctx)

    def init_cache(self, B, S_max, dtype=None):
        return T.init_cache(self.cfg, B, S_max, dtype)


def get_model(arch_or_cfg) -> Model:
    cfg = arch_or_cfg if isinstance(arch_or_cfg, ModelConfig) else get_config(arch_or_cfg)
    return Model(cfg)
