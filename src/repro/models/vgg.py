"""VGG family in functional JAX — the paper's own experimental models.

Structure (matches ``repro.configs.vgg_family.VGGConfig``):
  params = {
    "stages": {"s0": {"c0": {"w": (3,3,Cin,Cout), "b": (Cout,)}, ...}, ...},
    "fc":     {"f0": {"w": (Din,Dout), "b": (Dout,)}, ...},
    "out":    {"w": (D, n_classes), "b": (n_classes,)},
  }
Max-pool (2x2) after every stage; ReLU after every conv / fc.

The sequential conv/fc structure is what FedADP's NetChange manipulates
(core/netchange.py): widening duplicates output channels and splits the
*next* layer's incoming weights; deepening inserts identity convs (exact
under ReLU since activations are non-negative).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.vgg_family import VGGConfig


def _conv_init(key, cin, cout, dtype=jnp.float32):
    k1, _ = jax.random.split(key)
    fan_in = 3 * 3 * cin
    w = jax.random.normal(k1, (3, 3, cin, cout)) * math.sqrt(2.0 / fan_in)
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def _fc_init(key, din, dout, dtype=jnp.float32):
    w = jax.random.normal(key, (din, dout)) * math.sqrt(2.0 / din)
    return {"w": w.astype(dtype), "b": jnp.zeros((dout,), dtype)}


def init_params(key, cfg: VGGConfig, dtype=jnp.float32) -> Dict[str, Any]:
    params: Dict[str, Any] = {"stages": {}, "fc": {}}
    cin = cfg.in_channels
    for si, widths in enumerate(cfg.stages):
        stage = {}
        for li, cout in enumerate(widths):
            stage[f"c{li}"] = _conv_init(
                jax.random.fold_in(key, si * 100 + li), cin, cout, dtype)
            cin = cout
        params["stages"][f"s{si}"] = stage
    spatial = cfg.image_size // (2 ** len(cfg.stages))
    din = cin * spatial * spatial
    for fi, dout in enumerate(cfg.classifier):
        params["fc"][f"f{fi}"] = _fc_init(
            jax.random.fold_in(key, 10_000 + fi), din, dout, dtype)
        din = dout
    params["out"] = _fc_init(jax.random.fold_in(key, 20_000), din,
                             cfg.n_classes, dtype)
    return params


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply(params, cfg: VGGConfig, x):
    """x: (B, H, W, C) -> logits (B, n_classes)."""
    n_stages = len(params["stages"])
    for si in range(n_stages):
        stage = params["stages"][f"s{si}"]
        for li in range(len(stage)):
            x = jax.nn.relu(_conv(x, stage[f"c{li}"]))
        x = _maxpool(x)
    x = x.reshape(x.shape[0], -1)
    for fi in range(len(params["fc"])):
        p = params["fc"][f"f{fi}"]
        x = jax.nn.relu(x @ p["w"] + p["b"])
    out = params["out"]
    return x @ out["w"] + out["b"]


def loss_fn(params, cfg: VGGConfig, batch):
    """batch: {'x': (B,H,W,C), 'y': (B,) int labels}."""
    logits = apply(params, cfg, batch["x"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    loss = (logz - ll).mean()
    acc = (logits.argmax(-1) == batch["y"]).mean()
    return loss, acc
