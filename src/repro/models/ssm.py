"""Recurrent blocks: RG-LRU (Griffin/RecurrentGemma) and xLSTM (mLSTM, sLSTM).

All three expose a full-sequence path (train / prefill) and an O(1)-state
decode path. The RG-LRU is a diagonal linear recurrence and uses
``jax.lax.associative_scan``; mLSTM/sLSTM use a sequential ``lax.scan``
over time (mLSTM's chunkwise-parallel form is a §Perf candidate).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, dense_init, gelu

RG_LRU_C = 8.0


# ------------------------------------------------------------------ RG-LRU

def rglru_init(key, cfg, dtype):
    D, R = cfg.d_model, cfg.d_rnn
    cw = cfg.ssm.conv_width
    ks = jax.random.split(key, 6)
    return {
        "win": dense_init(ks[0], (D, R), dtype),
        "wgate": dense_init(ks[1], (D, R), dtype),
        "conv": dense_init(ks[2], (cw, R), dtype, fan_in=cw),
        "wa": dense_init(ks[3], (R, R), dtype),
        "ba": jnp.zeros((R,), dtype),
        "wx": dense_init(ks[4], (R, R), dtype),
        "bx": jnp.zeros((R,), dtype),
        # a = exp(-c * softplus(lam) * r); init for slow decay
        "lam": jnp.full((R,), -4.0, dtype),
        "wout": dense_init(ks[5], (R, D), dtype),
    }


def _rglru_gates(p, uc):
    r = jax.nn.sigmoid(uc @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(uc @ p["wx"] + p["bx"])
    log_a = (-RG_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = scale * (i.astype(jnp.float32) * uc.astype(jnp.float32))
    return a, b


def rglru_seq(p, x, state=None, *, return_state=False):
    """x: (B,S,D) -> (y, new_state). Linear diagonal recurrence via
    associative scan: h_t = a_t * h_{t-1} + b_t."""
    g = x @ p["wgate"]
    u = x @ p["win"]
    uc, conv_state = causal_conv1d(u, p["conv"],
                                   None if state is None else state["conv"])
    a, b = _rglru_gates(p, uc)                                # fp32 (B,S,R)
    if state is not None:
        b = b.at[:, 0].add(a[:, 0] * state["h"].astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b2 + a2 * b1

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    y = (h * gelu(g)) @ p["wout"]
    new_state = None
    if return_state:
        new_state = {"h": h[:, -1], "conv": conv_state}
    return y, new_state


def rglru_decode(p, x, state):
    """x: (B,1,D); state {'h': (B,R), 'conv': (B,cw-1,R)}."""
    g = x @ p["wgate"]
    u = x @ p["win"]
    uc, conv_state = causal_conv1d(u, p["conv"], state["conv"])
    a, b = _rglru_gates(p, uc)                                # (B,1,R)
    h = (a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]).astype(x.dtype)
    y = (h[:, None] * gelu(g)) @ p["wout"]
    return y, {"h": h, "conv": conv_state}


def init_rglru_state(cfg, B, dtype):
    R, cw = cfg.d_rnn, cfg.ssm.conv_width
    return {"h": jnp.zeros((B, R), dtype),
            "conv": jnp.zeros((B, cw - 1, R), dtype)}


# ------------------------------------------------------------------ mLSTM

def mlstm_init(key, cfg, dtype):
    D = cfg.d_model
    Dm = 2 * D
    H = cfg.ssm.n_heads
    cw = cfg.ssm.conv_width
    ks = jax.random.split(key, 8)
    return {
        "wup": dense_init(ks[0], (D, Dm), dtype),
        "wz": dense_init(ks[1], (D, Dm), dtype),
        "conv": dense_init(ks[2], (cw, Dm), dtype, fan_in=cw),
        "wq": dense_init(ks[3], (Dm, Dm), dtype),
        "wk": dense_init(ks[4], (Dm, Dm), dtype),
        "wv": dense_init(ks[5], (Dm, Dm), dtype),
        "wi": dense_init(ks[6], (Dm, H), dtype),
        "bi": jnp.zeros((H,), dtype),
        "wf": dense_init(ks[7], (Dm, H), dtype),
        "bf": jnp.linspace(3.0, 6.0, H).astype(dtype),  # long-memory init
        "gn": jnp.zeros((Dm,), dtype),
        "wdown": dense_init(jax.random.fold_in(key, 9), (Dm, D), dtype),
    }


def _mlstm_qkvif(p, cfg, x, conv_state):
    B, S, _ = x.shape
    H = cfg.ssm.n_heads
    Dm = p["wup"].shape[1]
    dh = Dm // H
    xu = x @ p["wup"]
    z = x @ p["wz"]
    xc, conv_state = causal_conv1d(xu, p["conv"], conv_state)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(B, S, H, dh) * (dh ** -0.5)
    k = (xc @ p["wk"]).reshape(B, S, H, dh) * (dh ** -0.5)
    v = (xu @ p["wv"]).reshape(B, S, H, dh)
    i = (xc @ p["wi"] + p["bi"]).astype(jnp.float32)          # (B,S,H) log-i
    f = (xc @ p["wf"] + p["bf"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f)
    return q, k, v, i, logf, z, conv_state


def _mlstm_step(state, qkvif):
    """Stabilized mLSTM cell. state: C (B,H,dh,dh), n (B,H,dh), m (B,H)."""
    C, n, m = state
    q, k, v, i, logf = qkvif                                  # (B,H,dh)x3,(B,H)x2
    m_new = jnp.maximum(logf + m, i)
    i_p = jnp.exp(i - m_new)[..., None]
    f_p = jnp.exp(logf + m - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = f_p[..., None] * C + i_p[..., None] * (vf[..., :, None] * kf[..., None, :])
    n = f_p * n + i_p * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def _gn(h, scale, eps=1e-6):
    """Per-head group norm over the head dim. h: (..., H, dh)."""
    h32 = h.astype(jnp.float32)
    mu = h32.mean(-1, keepdims=True)
    var = h32.var(-1, keepdims=True)
    out = (h32 - mu) * jax.lax.rsqrt(var + eps)
    flat = out.reshape(out.shape[:-2] + (-1,))
    return flat * (1.0 + scale.astype(jnp.float32))


def mlstm_seq(p, cfg, x, state=None, *, return_state=False):
    B, S, D = x.shape
    H = cfg.ssm.n_heads
    Dm = p["wup"].shape[1]
    dh = Dm // H
    conv_state = None if state is None else state["conv"]
    q, k, v, i, logf, z, conv_state = _mlstm_qkvif(p, cfg, x, conv_state)
    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def body(carry, xs):
        return _mlstm_step(carry, xs)

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i.transpose(1, 0, 2), logf.transpose(1, 0, 2))
    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3)                              # (B,S,H,dh)
    out = _gn(h, p["gn"]).astype(x.dtype)
    y = (out * jax.nn.silu(z)) @ p["wdown"]
    new_state = None
    if return_state:
        new_state = {"C": C, "n": n, "m": m, "conv": conv_state}
    return y, new_state


def mlstm_decode(p, cfg, x, state):
    q, k, v, i, logf, z, conv_state = _mlstm_qkvif(p, cfg, x, state["conv"])
    (C, n, m), h = _mlstm_step((state["C"], state["n"], state["m"]),
                               (q[:, 0], k[:, 0], v[:, 0], i[:, 0], logf[:, 0]))
    out = _gn(h, p["gn"]).astype(x.dtype)[:, None]
    y = (out * jax.nn.silu(z)) @ p["wdown"]
    return y, {"C": C, "n": n, "m": m, "conv": conv_state}


def init_mlstm_state(cfg, B, dtype):
    H = cfg.ssm.n_heads
    Dm = 2 * cfg.d_model
    dh = Dm // H
    cw = cfg.ssm.conv_width
    return {"C": jnp.zeros((B, H, dh, dh), jnp.float32),
            "n": jnp.zeros((B, H, dh), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32),
            "conv": jnp.zeros((B, cw - 1, Dm), dtype)}


# ------------------------------------------------------------------ sLSTM

def slstm_init(key, cfg, dtype):
    D = cfg.d_model
    H = cfg.ssm.n_heads
    dh = D // H
    ks = jax.random.split(key, 10)
    p = {}
    for n, kk in zip(("z", "i", "f", "o"), ks[:4]):
        p[f"w{n}"] = dense_init(kk, (D, D), dtype)
        p[f"b{n}"] = jnp.zeros((D,), dtype)
    for n, kk in zip(("z", "i", "f", "o"), ks[4:8]):
        p[f"r{n}"] = dense_init(kk, (H, dh, dh), dtype, fan_in=dh)
    p["bf_init"] = jnp.linspace(3.0, 6.0, D).astype(dtype)  # long-memory bias
    p["gn"] = jnp.zeros((D,), dtype)
    p["wout"] = dense_init(ks[8], (D, D), dtype)
    return p


def _slstm_recur(p, h_prev, H, dh):
    hp = h_prev.reshape(h_prev.shape[0], H, dh)
    out = {}
    for n in ("z", "i", "f", "o"):
        out[n] = jnp.einsum("bhd,hde->bhe", hp, p[f"r{n}"]).reshape(h_prev.shape)
    return out


def _slstm_step(p, state, xg, H, dh):
    """state: (c, n, m, h) each (B,D) fp32 (h in model dtype)."""
    c, nrm, m, h = state
    xz, xi, xf, xo = xg
    r = _slstm_recur(p, h, H, dh)
    z = jnp.tanh((xz + r["z"]).astype(jnp.float32))
    o = jax.nn.sigmoid((xo + r["o"]).astype(jnp.float32))
    i_log = (xi + r["i"]).astype(jnp.float32)
    f_log = (xf + r["f"] + p["bf_init"]).astype(jnp.float32)
    m_new = jnp.maximum(f_log + m, i_log)
    i_p = jnp.exp(i_log - m_new)
    f_p = jnp.exp(f_log + m - m_new)
    c = f_p * c + i_p * z
    nrm = f_p * nrm + i_p
    h_new = (o * c / jnp.maximum(nrm, 1.0)).astype(h.dtype)
    return (c, nrm, m_new, h_new)


def slstm_seq(p, cfg, x, state=None, *, return_state=False):
    B, S, D = x.shape
    H = cfg.ssm.n_heads
    dh = D // H
    xg = tuple((x @ p[f"w{n}"] + p[f"b{n}"]).transpose(1, 0, 2)
               for n in ("z", "i", "f", "o"))
    if state is None:
        state = (jnp.zeros((B, D), jnp.float32), jnp.zeros((B, D), jnp.float32),
                 jnp.full((B, D), -1e30, jnp.float32), jnp.zeros((B, D), x.dtype))
    else:
        state = (state["c"], state["n"], state["m"], state["h"])

    def body(carry, xs):
        new = _slstm_step(p, carry, xs, H, dh)
        return new, new[3]

    state, hs = jax.lax.scan(body, state, xg)
    h = hs.transpose(1, 0, 2)                                 # (B,S,D)
    out = _gn(h.reshape(B, S, H, dh), p["gn"]).astype(x.dtype)
    y = out @ p["wout"]
    new_state = None
    if return_state:
        c, nrm, m, hl = state
        new_state = {"c": c, "n": nrm, "m": m, "h": hl}
    return y, new_state


def slstm_decode(p, cfg, x, state):
    B = x.shape[0]
    D = x.shape[-1]
    H = cfg.ssm.n_heads
    dh = D // H
    xg = tuple((x[:, 0] @ p[f"w{n}"] + p[f"b{n}"]) for n in ("z", "i", "f", "o"))
    new = _slstm_step(p, (state["c"], state["n"], state["m"], state["h"]),
                      xg, H, dh)
    c, nrm, m, h = new
    out = _gn(h.reshape(B, H, dh), p["gn"]).astype(x.dtype)
    y = (out @ p["wout"])[:, None]
    return y, {"c": c, "n": nrm, "m": m, "h": h}


def init_slstm_state(cfg, B, dtype):
    D = cfg.d_model
    return {"c": jnp.zeros((B, D), jnp.float32),
            "n": jnp.zeros((B, D), jnp.float32),
            "m": jnp.full((B, D), -1e30, jnp.float32),
            "h": jnp.zeros((B, D), dtype)}
