"""Config-driven transformer stacks for all assigned architecture families.

Layers are organized as *pattern units* (the repeating layer group, e.g.
gemma3's 5xlocal+1xglobal): parameters of each unit position are stacked
over a leading ``n_units`` axis and the stack is traversed with
``jax.lax.scan`` — keeping HLO size proportional to the pattern length and
making NetChange depth transforms pure slice/concat on the stacked axis.
Layers that don't fill a whole unit (n_layers % pattern_len) live
unstacked under ``params["rem"]``.

Public API:
  init_params(key, cfg)                    -> params pytree
  forward(params, cfg, tokens, ...)        -> logits (B,S,V)
  prefill(params, cfg, tokens, ...)        -> (last_logits (B,V), cache)
  decode_step(params, cfg, token, cache, pos, ...) -> (logits (B,V), cache)
  init_cache(cfg, B, S_max, dtype)         -> cache pytree
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import dense_init, embed_init, mlp_apply, mlp_init, rms_norm
from repro.sharding.ctx import CPU_CTX, ShardCtx

Params = Dict[str, Any]


def _param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------- block init

def block_init(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("global", "local", "crossdec"):
        p = {"ln1": jnp.zeros((D,), dtype), "ln2": jnp.zeros((D,), dtype)}
        p["attn"] = (A.mla_init(ks[0], cfg, dtype) if cfg.mla
                     else A.attn_init(ks[0], cfg, dtype))
        if kind == "crossdec":
            p["lnx"] = jnp.zeros((D,), dtype)
            p["xattn"] = A.cross_attn_init(ks[1], cfg, dtype)
        if cfg.moe is not None:
            p["moe"] = M.moe_init(ks[2], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[2], cfg, D, cfg.d_ff, dtype)
        return p
    if kind == "rglru":
        return {"ln1": jnp.zeros((D,), dtype),
                "rg": S.rglru_init(ks[0], cfg, dtype),
                "ln2": jnp.zeros((D,), dtype),
                "mlp": mlp_init(ks[1], cfg, D, cfg.d_ff, dtype)}
    if kind == "mlstm":
        return {"ln1": jnp.zeros((D,), dtype),
                "mx": S.mlstm_init(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln1": jnp.zeros((D,), dtype),
                "sx": S.slstm_init(ks[0], cfg, dtype)}
    raise ValueError(kind)


# ------------------------------------------------------- block apply (seq)

def _sp_boundary(x, ctx):
    """Sequence-parallel residual boundary: shard S over the model axis so
    the partitioner lowers the TP partial-sum all-reduces into
    reduce-scatter + all-gather pairs (§Perf glm4 iteration 5)."""
    if not (getattr(ctx, "seq_parallel", False) and ctx.distributed):
        return x
    if x.shape[1] % ctx.model_size:
        return x
    from repro.models.attention import _csc
    return _csc(x, ctx, "data", ctx.model_axis, None)


def block_apply_seq(p, cfg, kind, x, positions, *, ctx, return_cache=False,
                    cache_len=None, enc_out=None):
    """Full-sequence block. Returns (x, cache_or_None)."""
    cache = None
    x = _sp_boundary(x, ctx)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("global", "local", "crossdec"):
        akind = "global" if kind == "crossdec" else kind
        if cfg.mla is not None:
            y, cache = A.mla_apply_seq(p["attn"], cfg, h, positions, ctx=ctx,
                                       return_cache=return_cache,
                                       cache_len=cache_len)
        else:
            y, cache = A.attn_apply_seq(p["attn"], cfg, h, positions,
                                        kind=akind, ctx=ctx,
                                        return_cache=return_cache,
                                        cache_len=cache_len)
        x = x + y
        if kind == "crossdec":
            hx = rms_norm(x, p["lnx"], cfg.norm_eps)
            ckv = A.cross_kv(p["xattn"], cfg, enc_out)
            x = x + A.cross_attn_apply(p["xattn"], cfg, hx, ckv, ctx=ctx)
            if return_cache:
                cache = dict(cache, xk=ckv["k"], xv=ckv["v"])
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            x = x + M.moe_apply(p["moe"], cfg, h2, ctx)
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg.mlp_kind, ctx)
        return x, cache
    if kind == "rglru":
        y, st = S.rglru_seq(p["rg"], h, None, return_state=return_cache)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp_kind, ctx)
        return x, st
    if kind == "mlstm":
        y, st = S.mlstm_seq(p["mx"], cfg, h, None, return_state=return_cache)
        return x + y, st
    if kind == "slstm":
        y, st = S.slstm_seq(p["sx"], cfg, h, None, return_state=return_cache)
        return x + y, st
    raise ValueError(kind)


def block_apply_decode(p, cfg, kind, x, pos, cache, *, ctx):
    """One-token block step. Returns (x, new_cache)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("global", "local", "crossdec"):
        akind = "global" if kind == "crossdec" else kind
        if cfg.mla is not None:
            y, cache_sa = A.mla_apply_decode(p["attn"], cfg, h, pos, cache, ctx=ctx)
            new_cache = cache_sa
        else:
            sa = {"k": cache["k"], "v": cache["v"]}
            y, cache_sa = A.attn_apply_decode(p["attn"], cfg, h, pos, sa,
                                              kind=akind, ctx=ctx)
            new_cache = dict(cache, **cache_sa)
        x = x + y
        if kind == "crossdec":
            hx = rms_norm(x, p["lnx"], cfg.norm_eps)
            ckv = {"k": cache["xk"], "v": cache["xv"]}
            x = x + A.cross_attn_apply(p["xattn"], cfg, hx, ckv, ctx=ctx)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            x = x + M.moe_apply(p["moe"], cfg, h2, ctx)
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg.mlp_kind, ctx)
        return x, new_cache
    if kind == "rglru":
        y, st = S.rglru_decode(p["rg"], h, cache)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp_kind, ctx)
        return x, st
    if kind == "mlstm":
        y, st = S.mlstm_decode(p["mx"], cfg, h, cache)
        return x + y, st
    if kind == "slstm":
        y, st = S.slstm_decode(p["sx"], cfg, h, cache)
        return x + y, st
    raise ValueError(kind)


def _block_cache_init(cfg, kind, B, S_max, dtype):
    if kind in ("global", "local"):
        if cfg.mla is not None:
            return A.init_mla_cache(cfg, B, S_max, dtype)
        return A.init_attn_cache(cfg, B, S_max, dtype, kind=kind)
    if kind == "crossdec":
        c = A.init_attn_cache(cfg, B, S_max, dtype, kind="global")
        H, hd = cfg.n_heads, cfg.resolved_head_dim
        T = cfg.encoder.n_ctx
        c["xk"] = jnp.zeros((B, T, H, hd), dtype)
        c["xv"] = jnp.zeros((B, T, H, hd), dtype)
        return c
    if kind == "rglru":
        return S.init_rglru_state(cfg, B, dtype)
    if kind == "mlstm":
        return S.init_mlstm_state(cfg, B, dtype)
    if kind == "slstm":
        return S.init_slstm_state(cfg, B, dtype)
    raise ValueError(kind)


# ----------------------------------------------------------- whisper encoder

def _enc_block_init(key, cfg, dtype):
    D = cfg.encoder.d_model
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.zeros((D,), dtype),
            "attn": A.attn_init(ks[0], cfg, dtype),
            "ln2": jnp.zeros((D,), dtype),
            "mlp": mlp_init(ks[1], cfg, D, cfg.d_ff, dtype)}


def _enc_block_apply(p, cfg, x, positions, *, ctx):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = A._qkv(p["attn"], cfg, h)
    from repro.models.layers import apply_rope
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    B, S = q.shape[0], q.shape[1]
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    q5 = q.reshape(B, S, KV, cfg.n_heads // KV, hd)
    q5, k, v = A.apply_head_layout_seq(q5, k, v, ctx)
    out = A.attend(q5, k, v, positions, positions, causal=False, window=0,
                   ctx=ctx)
    x = x + out.reshape(x.shape[0], x.shape[1], -1) @ p["attn"]["wo"]
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h2, cfg.mlp_kind, ctx)


def encode(params, cfg, frames, *, ctx=CPU_CTX):
    """Whisper encoder over stub frame embeddings (B, n_ctx, D)."""
    x = frames
    positions = jnp.arange(frames.shape[1])

    def body(h, unit_p):
        return _enc_block_apply(unit_p, cfg, h, positions, ctx=ctx), None

    x, _ = jax.lax.scan(body, x, params["units"])
    return rms_norm(x, params["final_ln"], cfg.norm_eps)


# ----------------------------------------------------------------- init

def init_params(key, cfg: ModelConfig) -> Params:
    cfg.validate()
    dtype = _param_dtype(cfg)
    D, V = cfg.d_model, cfg.vocab_size
    k_embed, k_units, k_rem, k_head, k_enc = jax.random.split(key, 5)

    params: Params = {"embed": embed_init(k_embed, (V, D), dtype),
                      "final_ln": jnp.zeros((D,), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (D, V), dtype)

    plen, n_units = cfg.pattern_len, cfg.n_units
    if n_units:
        unit = {}
        for i, kind in enumerate(cfg.layer_pattern):
            ki = jax.random.fold_in(k_units, i)
            stacked = jax.vmap(lambda k: block_init(k, cfg, kind, dtype))(
                jax.random.split(ki, n_units))
            unit[f"b{i}"] = stacked
        params["units"] = unit
    rem = {}
    for i, kind in enumerate(cfg.rem_kinds):
        rem[f"b{i}"] = block_init(jax.random.fold_in(k_rem, i), cfg, kind, dtype)
    if rem:
        params["rem"] = rem

    if cfg.encoder is not None:
        enc_units = jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
            jax.random.split(k_enc, cfg.encoder.n_layers))
        params["encoder"] = {"units": enc_units,
                             "final_ln": jnp.zeros((D,), dtype)}
    return params


# ------------------------------------------------------------- embeddings

def _embed(params, cfg, tokens, aux):
    h = params["embed"][tokens].astype(_param_dtype(cfg))
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if cfg.frontend is not None and cfg.frontend.kind == "vision" and aux is not None:
        h = jnp.concatenate([aux.astype(h.dtype), h], axis=1)
    return h


def _logits(params, cfg, h, fp32=True):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out = h @ w
    return out.astype(jnp.float32) if fp32 else out


# ------------------------------------------------------------ seq traversal

def _traverse_seq(params, cfg, h, positions, *, ctx, return_cache,
                  cache_len=None, enc_out=None):
    """Scan units + unrolled remainder. Returns (h, caches|None)."""
    caches_u = None
    if cfg.n_units:
        def unit_body(hc, unit_p):
            hh = hc
            outs = {}
            for i, kind in enumerate(cfg.layer_pattern):
                hh, c = block_apply_seq(unit_p[f"b{i}"], cfg, kind, hh,
                                        positions, ctx=ctx,
                                        return_cache=return_cache,
                                        cache_len=cache_len, enc_out=enc_out)
                if return_cache:
                    outs[f"b{i}"] = c
            return hh, (outs if return_cache else None)

        if ctx.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if ctx.remat_policy == "dots" else None)
            body = jax.checkpoint(unit_body, policy=policy)
        else:
            body = unit_body
        h, caches_u = jax.lax.scan(body, h, params["units"])
    caches_r = {}
    for i, kind in enumerate(cfg.rem_kinds):
        h, c = block_apply_seq(params["rem"][f"b{i}"], cfg, kind, h, positions,
                               ctx=ctx, return_cache=return_cache,
                               cache_len=cache_len, enc_out=enc_out)
        if return_cache:
            caches_r[f"b{i}"] = c
    if not return_cache:
        return h, None
    cache = {}
    if caches_u is not None:
        cache["units"] = caches_u
    if caches_r:
        cache["rem"] = caches_r
    return h, cache


def forward_hidden(params, cfg: ModelConfig, tokens, *,
                   ctx: ShardCtx = CPU_CTX, aux=None):
    """Final-norm hidden states (B, S_total, D) — callers that chunk the
    vocab projection (big-V loss) use this instead of ``forward``."""
    h = _embed(params, cfg, tokens, aux)
    S = h.shape[1]
    positions = jnp.arange(S)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(params["encoder"], cfg, aux, ctx=ctx)
    h, _ = _traverse_seq(params, cfg, h, positions, ctx=ctx,
                         return_cache=False, enc_out=enc_out)
    return rms_norm(h, params["final_ln"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, ctx: ShardCtx = CPU_CTX,
            aux=None, fp32_logits=True):
    """Training forward: logits for every position. tokens: (B, S_text)."""
    h = forward_hidden(params, cfg, tokens, ctx=ctx, aux=aux)
    return _logits(params, cfg, h, fp32_logits)


def prefill(params, cfg: ModelConfig, tokens, *, ctx: ShardCtx = CPU_CTX,
            aux=None, cache_len=None):
    """Prefill: returns (last-position logits (B,V), cache)."""
    h = _embed(params, cfg, tokens, aux)
    S = h.shape[1]
    cache_len = cache_len or S
    positions = jnp.arange(S)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(params["encoder"], cfg, aux, ctx=ctx)
    h, cache = _traverse_seq(params, cfg, h, positions, ctx=ctx,
                             return_cache=True, cache_len=cache_len,
                             enc_out=enc_out)
    h = rms_norm(h[:, -1:], params["final_ln"], cfg.norm_eps)
    return _logits(params, cfg, h)[:, 0], cache


def decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                ctx: ShardCtx = CPU_CTX):
    """One decode step. token: (B,1) int32; pos: scalar int32 (position of
    the new token). Returns (logits (B,V), new_cache)."""
    h = _embed(params, cfg, token, None)
    new_cache: Dict[str, Any] = {}
    if cfg.n_units:
        def unit_body(hc, xs):
            unit_p, unit_c = xs
            hh = hc
            outs = {}
            for i, kind in enumerate(cfg.layer_pattern):
                hh, c = block_apply_decode(unit_p[f"b{i}"], cfg, kind, hh, pos,
                                           unit_c[f"b{i}"], ctx=ctx)
                outs[f"b{i}"] = c
            return hh, outs

        h, new_units = jax.lax.scan(unit_body, h,
                                    (params["units"], cache["units"]))
        new_cache["units"] = new_units
    if cfg.rem_kinds:
        new_rem = {}
        for i, kind in enumerate(cfg.rem_kinds):
            h, c = block_apply_decode(params["rem"][f"b{i}"], cfg, kind, h, pos,
                                      cache["rem"][f"b{i}"], ctx=ctx)
            new_rem[f"b{i}"] = c
        new_cache["rem"] = new_rem
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    return _logits(params, cfg, h)[:, 0], new_cache


def init_cache(cfg: ModelConfig, B, S_max, dtype=None) -> Params:
    dtype = dtype or _param_dtype(cfg)
    cache: Dict[str, Any] = {}
    if cfg.n_units:
        unit = {}
        for i, kind in enumerate(cfg.layer_pattern):
            one = _block_cache_init(cfg, kind, B, S_max, dtype)
            unit[f"b{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.n_units,) + x.shape), one)
        cache["units"] = unit
    if cfg.rem_kinds:
        cache["rem"] = {f"b{i}": _block_cache_init(cfg, kind, B, S_max, dtype)
                        for i, kind in enumerate(cfg.rem_kinds)}
    return cache
