"""Attention: blockwise (flash-style) pure-JAX path used for lowering +
training, plus decode-against-cache, GQA/MQA, sliding windows and
DeepSeek-style MLA.

The Pallas TPU kernel for the sliding-window serving hot path lives in
``repro.kernels.swa_attention``; this module is the XLA path that every
dry-run/smoke test exercises (Pallas CPU execution is interpret-only).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, dense_init, rms_norm,
                                 tp_row_matmul)
from repro.sharding.ctx import CPU_CTX, ShardCtx

NEG_INF = -1e30


def _pad_to(x, size, axis, value=0):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def head_layout(H: int, KV: int, model_size: int):
    """How to map attention heads onto the model axis (DESIGN.md §5 /
    EXPERIMENTS.md §Perf iteration 1):
      'kv'      — shard the KV-head dim (KV % m == 0): zero collectives.
      'expand'  — repeat k/v G-fold to H heads, shard H: pays G x kv HBM
                  traffic, zero collectives.
      'replicate' — heads not divisible (e.g. 14 or 12 heads on a 16-way
                  axis): attention is data-parallel only. Without this the
                  partitioner splits the CONTRACTING head_dim and inserts a
                  per-(layer x q-block x kv-block) score all-reduce — the
                  46 TB/device pathology in the internvl2 baseline."""
    if model_size <= 1:
        return "single"
    if KV % model_size == 0:
        return "kv"
    if H % model_size == 0:
        return "expand"
    return "replicate"


def _dp_extent(ctx) -> int:
    n = 1
    for a in (ctx.data_axes or ()):
        n *= ctx.mesh.shape[a]
    return max(n, 1)


def _csc(x, ctx, *entries):
    """with_sharding_constraint if a mesh is live."""
    if not ctx.distributed:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ctx.data_axes if ctx.data_axes else None
    resolved = [dp if e == "data" else e for e in entries]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*resolved)))


def apply_head_layout_seq(q5, k, v, ctx):
    """q5: (B,S,KV,G,hd); k,v: (B,S,KV,hd). Returns constrained (q5,k,v)
    possibly with k/v expanded to flat heads (KV=H, G=1)."""
    B, S, KV, G, hd = q5.shape
    layout = head_layout(KV * G, KV, ctx.model_size)
    if layout == "single":
        return q5, k, v
    if layout == "expand":
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        q5 = q5.reshape(B, S, KV * G, 1, hd)
        layout = "kv"
    ax = ctx.model_axis if layout == "kv" else None
    q5 = _csc(q5, ctx, "data", None, ax, None, None)
    k = _csc(k, ctx, "data", None, ax, None)
    v = _csc(v, ctx, "data", None, ax, None)
    return q5, k, v


def blockwise_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                        block_q=512, block_kv=512, banded=True,
                        causal_skip=False):
    """Memory-O(block^2) attention. q: (B,Sq,KV,G,hd) (G = query heads per
    kv head); k,v: (B,Sk,KV,hd); q_pos: (Sq,), kv_pos: (Sk,) absolute
    positions (-1 => masked key). Returns (B,Sq,KV*G,hd).

    ``banded`` (window > 0 only) restricts each query block to the
    ~(window+block_q)/block_kv kv blocks it can actually see — assumes
    q_pos/kv_pos are contiguous ascending (true for train/prefill).
    ``causal_skip`` restricts the kv scan of query block i to blocks
    <= i (assumes q and kv are position-aligned, Sq == Sk).
    """
    B, Sq, KV, G, hd = q.shape
    H = KV * G
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_kv, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    scale = hd ** -0.5

    qp = _pad_to(q, nq * bq, 1) * scale
    qpos_p = _pad_to(q_pos, nq * bq, 0, value=-1)
    kp = _pad_to(k, nk * bk, 1)
    vp = _pad_to(v, nk * bk, 1)
    kpos_p = _pad_to(kv_pos, nk * bk, 0, value=-1)

    qb = qp.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qpb = qpos_p.reshape(nq, bq)

    use_banded = banded and window > 0 and causal

    def attend_block(qi, qpi, kb, vb, kpi, extra_valid):
        # qi (B,bq,KV,G,hd) kb (B,bk,KV,hd) -> scores (B,KV,G,bq,bk) fp32
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kb,
                       preferred_element_type=jnp.float32)
        valid = (kpi >= 0) & extra_valid                      # (bk,)
        mask = jnp.broadcast_to(valid[None, :], (bq, bk))
        if causal:
            mask = mask & (qpi[:, None] >= kpi[None, :])
        if window > 0:
            mask = mask & (qpi[:, None] - kpi[None, :] < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        return s

    def inner_step(carry, kb, vb, kpi, extra_valid, qi, qpi):
        m, l, acc = carry
        s = attend_block(qi, qpi, kb, vb, kpi, extra_valid)   # (B,KV,G,bq,bk)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc)

    def one_q_block(args):
        i, qi, qpi = args
        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)

        if use_banded:
            q_start = i * bq
            span = window + bq - 1
            nrel = -(-span // bk) + 1
            base = ((q_start - window + 1) // bk) * bk

            def body(j, carry):
                nominal = base + j * bk
                start = jnp.clip(nominal, 0, nk * bk - bk)
                ok = (nominal >= 0) & (nominal < nk * bk)
                kb = jax.lax.dynamic_slice_in_dim(kp, start, bk, 1)
                vb = jax.lax.dynamic_slice_in_dim(vp, start, bk, 1)
                kpi = jax.lax.dynamic_slice_in_dim(kpos_p, start, bk, 0)
                return inner_step(carry, kb, vb, kpi, ok, qi, qpi)

            m, l, acc = jax.lax.fori_loop(0, nrel, body, (m0, l0, a0))
        elif causal_skip and causal and Sq == Sk and bq == bk:
            def body(j, carry):
                kb = jax.lax.dynamic_slice_in_dim(kp, j * bk, bk, 1)
                vb = jax.lax.dynamic_slice_in_dim(vp, j * bk, bk, 1)
                kpi = jax.lax.dynamic_slice_in_dim(kpos_p, j * bk, bk, 0)
                return inner_step(carry, kb, vb, kpi, True, qi, qpi)

            m, l, acc = jax.lax.fori_loop(0, i + 1, body, (m0, l0, a0))
        else:
            kbs = kp.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
            vbs = vp.reshape(B, nk, bk, KV, hd).transpose(1, 0, 2, 3, 4)
            kps = kpos_p.reshape(nk, bk)

            def body(carry, xs):
                kb, vb, kpi = xs
                return inner_step(carry, kb, vb, kpi, True, qi, qpi), None

            (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kbs, vbs, kps))

        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KV,G,bq,hd)
        return out

    idx = jnp.arange(nq)
    outs = jax.lax.map(one_q_block, (idx, qb, qpb))           # (nq,B,KV,G,bq,hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, H, hd)
    return out[:, :Sq].astype(q.dtype)


def attend(q5, k, v, q_pos, kv_pos, *, causal, window, ctx,
           banded=False, causal_skip=False):
    """Backend-selected full-sequence attention (DESIGN.md §11): the one
    entry every train/prefill call site goes through. ``ctx.attn_backend``
    picks the implementation — "auto" trains through the fused Pallas
    flash kernel on TPU and keeps this module's ``blockwise_attention``
    as the XLA path elsewhere; "flash"/"blockwise" force a backend (the
    flash jnp fallback off-TPU is the vectorised reference, so forcing
    it is cheap). ``banded``/``causal_skip`` are blockwise-only scan
    micro-optimisations; the flash kernel masks natively."""
    backend = getattr(ctx, "attn_backend", "auto")
    if backend == "auto":
        from repro.kernels.fedavg.fedavg import on_tpu
        backend = "flash" if on_tpu() else "blockwise"
    if backend == "flash":
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q5, k, v, q_pos, kv_pos, causal=causal,
                               window=window, block_q=ctx.block_q,
                               block_kv=ctx.block_kv)
    if backend != "blockwise":
        raise ValueError(f"unknown attn_backend {backend!r}")
    return blockwise_attention(q5, k, v, q_pos, kv_pos, causal=causal,
                               window=window, block_q=ctx.block_q,
                               block_kv=ctx.block_kv, banded=banded,
                               causal_skip=causal_skip)


def decode_attention(q, k_cache, v_cache, key_pos, q_pos, *, window=0):
    """One-token attention vs a cache. q: (B,H,hd); caches (B,Sc,KV,hd);
    key_pos: (Sc,) absolute positions of cache slots (-1 = unwritten)."""
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32)
    valid = (key_pos >= 0) & (key_pos <= q_pos)
    if window > 0:
        valid = valid & (q_pos - key_pos < window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


def ring_positions(pos, size):
    """Absolute positions held by a ring buffer of ``size`` after writing
    position ``pos`` at slot pos % size. Unwritten slots come out < 0."""
    slots = jnp.arange(size)
    return pos - ((pos - slots) % size)


# ---------------------------------------------------------------- GQA layer

def attn_init(key, cfg, dtype, *, cross=False):
    D, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype),
        "wk": dense_init(ks[1], (D, KV * hd), dtype),
        "wv": dense_init(ks[2], (D, KV * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _qkv(p, cfg, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KV, hd),
            v.reshape(B, S, KV, hd))


def attn_apply_seq(p, cfg, x, positions, *, kind="global", ctx: ShardCtx = CPU_CTX,
                   return_cache=False, cache_len=None):
    """Full-sequence self-attention (train / prefill).

    positions: (S,). Returns (y, cache|None); cache k/v are post-RoPE.
    For local layers the prefill cache keeps only the last ``window`` slots.
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.window if kind == "local" else 0
    q5 = q.reshape(B, S, KV, H // KV, hd)
    q5a, ka, va = apply_head_layout_seq(q5, k, v, ctx)
    out = attend(q5a, ka, va, positions, positions, causal=True,
                 window=window, ctx=ctx, banded=ctx.banded_local,
                 causal_skip=ctx.causal_skip)
    y = tp_row_matmul(out.reshape(B, S, -1), p["wo"], ctx)
    cache = None
    if return_cache:
        # cache the UNEXPANDED kv (layout expansion is attention-local)
        cache = _build_cache(k, v, positions, window, cache_len, S)
    return y, cache


def _build_cache(k, v, positions, window, cache_len, S):
    """Arrange prefill k/v into the decode cache layout."""
    if window > 0:
        W = min(window, cache_len or window)
        # ring layout: slot = pos % W for the last W positions
        last = k.shape[1]
        take = min(W, last)
        ks, vs = k[:, -take:], v[:, -take:]
        pos_tail = positions[-take:]
        slots = pos_tail % W
        ck = jnp.zeros((k.shape[0], W) + k.shape[2:], k.dtype).at[:, slots].set(ks)
        cv = jnp.zeros_like(ck).at[:, slots].set(vs)
        return {"k": ck, "v": cv}
    L = cache_len or S
    ck = jnp.zeros((k.shape[0], L) + k.shape[2:], k.dtype)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, 1)
    cv = jnp.zeros((v.shape[0], L) + v.shape[2:], v.dtype)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, 1)
    return {"k": ck, "v": cv}


def attn_apply_decode(p, cfg, x, pos, cache, *, kind="global",
                      ctx: ShardCtx = CPU_CTX):
    """One-token decode. x: (B,1,D); pos: scalar int32; cache {'k','v'}."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(p, cfg, x)
    pos_arr = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta)[:, 0]          # (B,H,hd)
    k = apply_rope(k, pos_arr, cfg.rope_theta)[:, 0]          # (B,KV,hd)
    v = v[:, 0]
    window = cfg.window if kind == "local" else 0
    Sc = cache["k"].shape[1]
    slot = (pos % Sc) if window > 0 else jnp.minimum(pos, Sc - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k[:, None], slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v[:, None], slot, 1)
    key_pos = ring_positions(pos, Sc) if window > 0 else jnp.arange(Sc)
    out = decode_attention(q, ck, cv, key_pos, pos, window=window)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": ck, "v": cv}


def init_attn_cache(cfg, B, S_max, dtype, *, kind="global"):
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L = min(cfg.window, S_max) if kind == "local" else S_max
    z = jnp.zeros((B, L, KV, hd), dtype)
    return {"k": z, "v": z}


# --------------------------------------------------------- cross attention

def cross_attn_init(key, cfg, dtype):
    D, H = cfg.d_model, cfg.n_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (D, H * hd), dtype),
        "wk": dense_init(ks[1], (D, H * hd), dtype),
        "wv": dense_init(ks[2], (D, H * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype, fan_in=H * hd),
    }


def cross_kv(p, cfg, enc_out):
    B, T, _ = enc_out.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, T, H, hd)
    v = (enc_out @ p["wv"]).reshape(B, T, H, hd)
    return {"k": k, "v": v}


def cross_attn_apply(p, cfg, x, kv, *, ctx: ShardCtx = CPU_CTX):
    """x: (B,S,D) attends to precomputed cross kv (B,T,H,hd), non-causal."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    T = kv["k"].shape[1]
    if S == 1:
        out = decode_attention(q[:, 0], kv["k"], kv["v"],
                               jnp.zeros((T,), jnp.int32), jnp.int32(0))
        out = out[:, None]
    else:
        qpos = jnp.zeros((S,), jnp.int32)
        kpos = jnp.zeros((T,), jnp.int32)
        q5, k5, v5 = apply_head_layout_seq(q[:, :, :, None], kv["k"],
                                           kv["v"], ctx)
        out = attend(q5, k5, v5, qpos, kpos, causal=False, window=0,
                     ctx=ctx)
    return tp_row_matmul(out.reshape(B, S, -1), p["wo"], ctx)


# ------------------------------------------------------------------- MLA

def mla_init(key, cfg, dtype):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], (D, m.q_lora_rank), dtype),
        "qln": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qk), dtype),
        "wkv_a": dense_init(ks[2], (D, m.kv_lora_rank + m.qk_rope_dim), dtype),
        "kvln": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank,
                                    H * (m.qk_nope_dim + m.v_head_dim)), dtype),
        "wo": dense_init(ks[4], (H * m.v_head_dim, D), dtype,
                         fan_in=H * m.v_head_dim),
    }


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ p["wq_a"], p["qln"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    qn, qr = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _mla_ckv(p, cfg, x, positions):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    ckv = rms_norm(kv[..., : m.kv_lora_rank], p["kvln"], cfg.norm_eps)
    krope = kv[..., m.kv_lora_rank:][:, :, None, :]            # 1 shared head
    krope = apply_rope(krope, positions, cfg.rope_theta)[:, :, 0]
    return ckv, krope


def mla_apply_seq(p, cfg, x, positions, *, ctx: ShardCtx = CPU_CTX,
                  return_cache=False, cache_len=None):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qn, qr = _mla_q(p, cfg, x, positions)
    ckv, krope = _mla_ckv(p, cfg, x, positions)
    kv = (ckv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope_dim + m.v_head_dim)
    kn, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    q = jnp.concatenate([qn, qr], -1)
    k = jnp.concatenate([kn, jnp.broadcast_to(krope[:, :, None],
                                              kn.shape[:3] + (m.qk_rope_dim,))], -1)
    vp = _pad_to(v, q.shape[-1], -1)                            # pad v to qk dim
    q5 = q[:, :, :, None]                                       # (B,S,H,1,qk)
    q5 = q5.reshape(B, S, H, 1, q.shape[-1])
    q5, k, vp = apply_head_layout_seq(q5, k, vp, ctx)           # KV=H here
    out = attend(q5, k, vp, positions, positions, causal=True, window=0,
                 ctx=ctx, causal_skip=ctx.causal_skip)
    out = out[..., : m.v_head_dim]
    y = tp_row_matmul(out.reshape(B, S, -1), p["wo"], ctx)
    cache = None
    if return_cache:
        L = cache_len or S
        c1 = jnp.zeros((B, L, m.kv_lora_rank), ckv.dtype)
        c1 = jax.lax.dynamic_update_slice_in_dim(c1, ckv, 0, 1)
        c2 = jnp.zeros((B, L, m.qk_rope_dim), krope.dtype)
        c2 = jax.lax.dynamic_update_slice_in_dim(c2, krope, 0, 1)
        cache = {"ckv": c1, "krope": c2}
    return y, cache


def mla_apply_decode(p, cfg, x, pos, cache, *, ctx: ShardCtx = CPU_CTX):
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos_arr = jnp.full((1,), pos, jnp.int32)
    qn, qr = _mla_q(p, cfg, x, pos_arr)                        # (B,1,H,*)
    qn, qr = qn[:, 0], qr[:, 0]
    ckv1, krope1 = _mla_ckv(p, cfg, x, pos_arr)
    Sc = cache["ckv"].shape[1]
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv1, pos, 1)
    krope = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope1, pos, 1)
    if ctx.distributed and ckv.shape[0] % _dp_extent(ctx) == 0:
        # keep the latent cache batch-sharded through the layer scan —
        # without this the partitioner round-trips it through an
        # all-gather per layer (§Perf deepseek iteration 2)
        ckv = _csc(ckv, ctx, "data", None, None)
        krope = _csc(krope, ctx, "data", None, None)
    key_pos = jnp.arange(Sc)
    valid = (key_pos <= pos)
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_dim + m.v_head_dim)
    wk, wv = wkv_b[..., : m.qk_nope_dim], wkv_b[..., m.qk_nope_dim:]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    if ctx.mla_absorb:
        # fold wkv_b into q / out: scores live in the latent space.
        q_abs = jnp.einsum("bhn,rhn->bhr", qn, wk)             # (B,H,r)
        s = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhe,bse->bhs", qr, krope,
                          preferred_element_type=jnp.float32)) * scale
        s = jnp.where(valid[None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhs,bsr->bhr", pr.astype(ckv.dtype), ckv)
        out = jnp.einsum("bhr,rhv->bhv", lat, wv)
    else:
        kv = jnp.einsum("bsr,rhx->bshx", ckv, wkv_b)
        kn, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]
        s = (jnp.einsum("bhn,bshn->bhs", qn, kn,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhe,bse->bhs", qr, krope,
                          preferred_element_type=jnp.float32)) * scale
        s = jnp.where(valid[None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhs,bshv->bhv", pr.astype(v.dtype), v)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"ckv": ckv, "krope": krope}


def init_mla_cache(cfg, B, S_max, dtype):
    m = cfg.mla
    return {"ckv": jnp.zeros((B, S_max, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((B, S_max, m.qk_rope_dim), dtype)}
