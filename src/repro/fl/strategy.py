"""The Strategy protocol: every FL method as one round contract.

The survey framing (Fan et al., 2023; HeteroFL) — and the paper's own
Algorithm 1 — describe a federated round as

    distribute -> local train -> collect -> aggregate

and every method we implement is an instance of that contract.  This
module makes the contract the API:

  * ``init_state(key)``                      server state at round 0,
  * ``distribute(state, r, k)``              params client k trains on,
  * ``collect(state, r, k, trained)``        client k's server-side update,
  * ``aggregate(state, r, updates)``         next server state from the
                                             participating ``(k, update)``
                                             pairs (partial participation
                                             = a subset of clients),
  * ``client_view(state, k, r)``             client k's current params for
                                             evaluation / deployment.

State shape is strategy-owned: FedADP's state is the single global
parameter tree (``kind = "global"``); the per-client baselines carry a
list of per-client trees (``kind = "per_client"``).  Orchestration —
rounds, participation schedules, callbacks, checkpointing — lives in
``fl/federation.py``; execution (who actually runs local training) lives
in ``fl/backends.py``.  Strategies only define the method's math, by
delegating to the ``repro.core`` implementations, so the literal
algorithms stay the single source of truth.

Layout is execution-owned, not strategy-owned: on the loop backend a
strategy's math runs on the tree-shaped reference layout, while the
unified backend routes the SAME math through the packed parameter plane
(``core.plane`` — one contiguous ``(K, P)`` buffer per round, one fused
aggregation pass). Strategies never see the plane; the aggregation
primitives they delegate to (``core.aggregation.fedavg`` /
``fedavg_masked``) pack internally, so Eq. 1 has exactly one
implementation under both backends.
"""
from __future__ import annotations

from typing import Any, List, Protocol, Sequence, Tuple, runtime_checkable

import jax

from repro.core import ClusteredFL, FedADP, FlexiFed, Standalone, vgg_chain
from repro.core.netchange import NARROW_MODES  # noqa: F401  (re-export; the
                                               # canonical home is core)

Update = Tuple[int, Any]          # (client index, collected update)

METHODS = ("fedadp", "clustered", "flexifed", "standalone")
FILLERS = ("zero", "global")


@runtime_checkable
class Strategy(Protocol):
    """Round contract every FL method implements (see module docstring)."""
    name: str                     # method id ("fedadp", "clustered", ...)
    kind: str                     # "global" | "per_client" state shape
    n_samples: Sequence[int]      # per-client dataset sizes (W_k weights)

    @property
    def n_clients(self) -> int: ...

    def init_state(self, key) -> Any: ...

    def distribute(self, state, round_idx: int, k: int) -> Any: ...

    def collect(self, state, round_idx: int, k: int, trained) -> Any: ...

    def aggregate(self, state, round_idx: int,
                  updates: Sequence[Update]) -> Any: ...

    def client_view(self, state, k: int, round_idx: int = 0) -> Any: ...


class FedADPStrategy:
    """FedADP (Algorithm 1) as a Strategy. State = the global tree.

    Coverage knobs (semantics single-sourced in ``core.aggregation``):

    ``filler`` selects the aggregation rule for regions a client doesn't
    cover (DESIGN.md §4):
      * "zero"    — the paper: the zero/identity filler ``up()`` inserts
                    participates in the average,
      * "global"  — FedADP-U: uncovered coordinates keep the server's
                    current values (the update is mask-folded onto the
                    global tree before averaging), so they are not pulled
                    toward the filler.  Formerly a one-off method body in
                    the simulator; now just a strategy option.
    ``coverage`` picks which coordinates count as covered ("loose" — the
    reference reading, identity-conv taps included — or "strict").
    ``agg_mode="coverage"`` replaces Eq. 1 with the HeteroFL-style
    renormalized average over covering clients (uncovered coordinates
    keep the server's values; ``filler`` is then irrelevant).
    """
    name = "fedadp"
    kind = "global"

    def __init__(self, family, client_cfgs, n_samples, *,
                 narrow_mode: str = "paper", filler: str = "zero",
                 coverage: str = "loose", agg_mode: str = "filler",
                 base_seed: int = 0, agg_layout: str = "auto",
                 k_chunk=None, wire: str = "f32",
                 wire_tile: int = 256, wire_sparse: bool = False,
                 compute_dtype: str = "f32", attn_backend: str = "auto"):
        if filler not in FILLERS:
            raise ValueError(f"filler={filler!r}, expected one of {FILLERS}")
        self.algo = FedADP(family, client_cfgs, n_samples,
                           narrow_mode=narrow_mode, coverage=coverage,
                           agg_mode=agg_mode, base_seed=base_seed,
                           agg_layout=agg_layout, k_chunk=k_chunk)
        self.filler = filler
        self.coverage = coverage
        self.agg_mode = agg_mode
        self.narrow_mode = narrow_mode   # backends read these: the unified
        self.base_seed = base_seed       # engine must down() the same way
                                         # and draw the same per-round
                                         # To-Wider mappings as the loop
        self.agg_layout = agg_layout     # ...and aggregate with the same
        self.k_chunk = k_chunk           # layout / streaming chunk
        self.wire = wire                 # client->server payload encoding
        self.wire_tile = wire_tile       # (core.quant; the unified engine
        self.wire_sparse = wire_sparse   # validates the combination)
        self.compute_dtype = compute_dtype   # local-training precision
        self.attn_backend = attn_backend     # and attention backend (the
                                             # unified engine validates +
                                             # applies both)
        self.family = family
        self.client_cfgs = list(self.algo.client_cfgs)
        self.n_samples = list(n_samples)
        self.global_cfg = self.algo.global_cfg

    @property
    def n_clients(self) -> int:
        return len(self.client_cfgs)

    def init_state(self, key):
        return self.algo.init_global(key)

    def distribute(self, state, round_idx: int, k: int):
        return self.algo.distribute(state, round_idx, k)

    def collect(self, state, round_idx: int, k: int, trained):
        up = self.algo.collect(trained, round_idx, k)
        if self.filler == "zero" or self.agg_mode == "coverage":
            # coverage-mode aggregation reads its own masks — the update
            # needs no fold here
            return up
        mask = self.algo.coverage_mask(round_idx, k)
        return jax.tree.map(lambda u, m, g: u * m + g * (1 - m),
                            up, mask, state)

    def aggregate(self, state, round_idx: int, updates: Sequence[Update]):
        selected = [k for k, _ in updates]
        return self.algo.aggregate([u for _, u in updates], selected,
                                   round_idx=round_idx, global_params=state)

    def client_view(self, state, k: int, round_idx: int = 0):
        return self.algo.distribute(state, round_idx, k)


class _PerClientStrategy:
    """Shared scaffolding for methods whose state is the list of client
    parameter trees; subclasses plug the core algorithm in ``_algo``."""
    kind = "per_client"

    def __init__(self, family, client_cfgs, n_samples):
        self.family = family
        self.client_cfgs = list(client_cfgs)
        self.n_samples = list(n_samples)

    @property
    def n_clients(self) -> int:
        return len(self.client_cfgs)

    def init_state(self, key) -> List:
        return [self.family.init(jax.random.fold_in(key, k), c)
                for k, c in enumerate(self.client_cfgs)]

    def distribute(self, state, round_idx: int, k: int):
        return state[k]

    def collect(self, state, round_idx: int, k: int, trained):
        return trained

    def aggregate(self, state, round_idx: int, updates: Sequence[Update]):
        new = list(state)
        for k, u in updates:
            new[k] = u
        return self._algo.aggregate(new, [k for k, _ in updates])

    def client_view(self, state, k: int, round_idx: int = 0):
        return state[k]


class StandaloneStrategy(_PerClientStrategy):
    """Purely local training — aggregate is the identity."""
    name = "standalone"

    def __init__(self, family, client_cfgs, n_samples):
        super().__init__(family, client_cfgs, n_samples)
        self._algo = Standalone(self.client_cfgs, self.n_samples)


class ClusteredStrategy(_PerClientStrategy):
    """FedAvg within same-architecture clusters (∩ participants)."""
    name = "clustered"

    def __init__(self, family, client_cfgs, n_samples):
        super().__init__(family, client_cfgs, n_samples)
        self._algo = ClusteredFL(self.client_cfgs, self.n_samples)


class FlexiFedStrategy(_PerClientStrategy):
    """Clustered-Common: shared chain prefix across participants, the
    personalized remainder within (cluster ∩ participants)."""
    name = "flexifed"

    def __init__(self, family, client_cfgs, n_samples, chain_fn=vgg_chain):
        super().__init__(family, client_cfgs, n_samples)
        self._algo = FlexiFed(self.client_cfgs, self.n_samples, chain_fn)


def make_strategy(method: str, family, client_cfgs, n_samples, *,
                  narrow_mode: str = "paper", filler: str = "zero",
                  coverage: str = "loose", agg_mode: str = "filler",
                  base_seed: int = 0, agg_layout: str = "auto",
                  k_chunk=None, wire: str = "f32", wire_tile: int = 256,
                  wire_sparse: bool = False, compute_dtype: str = "f32",
                  attn_backend: str = "auto") -> Strategy:
    """Strategy factory keyed on the method names ``FLRunConfig`` uses."""
    if method == "fedadp":
        return FedADPStrategy(family, client_cfgs, n_samples,
                              narrow_mode=narrow_mode, filler=filler,
                              coverage=coverage, agg_mode=agg_mode,
                              base_seed=base_seed, agg_layout=agg_layout,
                              k_chunk=k_chunk, wire=wire,
                              wire_tile=wire_tile, wire_sparse=wire_sparse,
                              compute_dtype=compute_dtype,
                              attn_backend=attn_backend)
    if method == "standalone":
        return StandaloneStrategy(family, client_cfgs, n_samples)
    if method == "clustered":
        return ClusteredStrategy(family, client_cfgs, n_samples)
    if method == "flexifed":
        return FlexiFedStrategy(family, client_cfgs, n_samples)
    raise ValueError(f"method={method!r}, expected one of {METHODS}")
