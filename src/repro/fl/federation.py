"""Federation: the orchestrator that owns rounds, participation,
metrics callbacks, and checkpoint/resume.

One object, one loop::

    strategy = FedADPStrategy(family, cfgs, n_samples)
    backend  = LoopBackend(family, cfgs, samplers, local_epochs=2, lr=0.05)
    fed      = Federation(strategy, backend, rounds=20, eval_batch=test)
    result   = fed.run(jax.random.PRNGKey(0))

Responsibilities are split three ways (DESIGN.md §7):
  * the **Strategy** defines the method's math (fl/strategy.py),
  * the **backend** executes a round (fl/backends.py: LoopBackend /
    UnifiedBackend),
  * the **Federation** owns everything around the rounds: which clients
    participate (``Participation``), when to evaluate, metrics callbacks,
    and durable ``(round, strategy state, rng)`` checkpoints through
    ``repro.checkpoint.store``.

Participation schedules:
  * full            — ``Participation()``: every client, every round,
  * fixed fraction  — ``Participation.cycle(f)``: a deterministic rotating
                      window of ``max(1, round(f*K))`` clients,
  * seeded sampling — ``Participation.sample(f, seed)``: a fresh
                      without-replacement draw per round, derived from
                      ``(seed, round)`` only — stateless, so resume needs
                      no sampler bookkeeping.

Checkpoints hold the strategy/backend state pytree (dtype-preserving,
bf16-safe — checkpoint/store.py) plus ``round``, ``history`` and the
data samplers' numpy rng states in the manifest, which is exactly the
state a run consumes: local optimizer state is re-initialized every
round and participation is stateless, so a resumed run reproduces the
uninterrupted one bit-for-bit (tests/test_federation.py).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint import load_plane, load_pytree, save_plane, save_pytree

PARTICIPATION_MODES = ("sample", "cycle")


@dataclass(frozen=True)
class Participation:
    """Per-round client selection. ``fraction=1.0`` is full participation;
    otherwise ``max(1, round(fraction*K))`` clients per round, chosen by
    ``mode`` ("sample": seeded without-replacement draw per round;
    "cycle": deterministic rotating window)."""
    fraction: float = 1.0
    seed: int = 0
    mode: str = "sample"

    def __post_init__(self):
        if not (0.0 < self.fraction <= 1.0):
            raise ValueError(f"participation fraction={self.fraction!r} "
                             "must be in (0, 1]")
        if self.mode not in PARTICIPATION_MODES:
            raise ValueError(f"participation mode={self.mode!r}, expected "
                             f"one of {PARTICIPATION_MODES}")

    @classmethod
    def sample(cls, fraction: float, seed: int = 0) -> "Participation":
        return cls(fraction=fraction, seed=seed, mode="sample")

    @classmethod
    def cycle(cls, fraction: float) -> "Participation":
        return cls(fraction=fraction, mode="cycle")

    @property
    def full(self) -> bool:
        return self.fraction >= 1.0

    def select(self, round_idx: int, n_clients: int) -> List[int]:
        if self.full:
            return list(range(n_clients))
        m = max(1, int(round(self.fraction * n_clients)))
        if self.mode == "cycle":
            start = (round_idx * m) % n_clients
            return sorted((start + i) % n_clients for i in range(m))
        rng = np.random.default_rng((self.seed, round_idx))
        return sorted(int(i) for i in
                      rng.choice(n_clients, size=m, replace=False))


# ------------------------------------------------------------ checkpoints
def checkpoint_path(directory: str, round_idx: int) -> str:
    return os.path.join(directory, f"round_{round_idx:04d}.npz")


def wire_checkpoint_path(path: str) -> str:
    """The sibling file holding the per-client error-feedback residual
    plane of a compressed run (``core.quant``): ``round_XXXX.wire.npz``
    next to ``round_XXXX.npz``.  Saved through ``checkpoint.save_plane``
    (bit-exact raw views), so a resumed compressed run reproduces the
    uninterrupted one bit-for-bit."""
    root, ext = os.path.splitext(path)
    return root + ".wire" + ext


def save_round_checkpoint(path: str, state, *, round_idx: int,
                          history: Sequence[float] = (),
                          samplers: Sequence = (),
                          meta: Optional[Dict[str, Any]] = None):
    """Persist ``(round, state, data-rng)``: the state pytree goes into the
    npz payload (dtype views preserved), everything else into the JSON
    manifest. Sampler rng state dicts (numpy ``bit_generator.state``) are
    plain JSON-serializable ints."""
    save_pytree(path, state, extra={
        "round": int(round_idx),
        "history": [float(h) for h in history],
        "sampler_rng": [s.rng.bit_generator.state for s in samplers],
        "meta": meta or {}})


def load_round_checkpoint(path: str, like=None):
    """Returns ``(state, extra)``; pass ``like`` (a template state pytree,
    e.g. a fresh ``backend.init_state``) to get arrays arranged into its
    structure and dtypes."""
    return load_pytree(path, like=like)


def restore_sampler_rngs(samplers: Sequence, extra: Dict[str, Any]):
    states = extra.get("sampler_rng") or []
    if states and len(states) != len(samplers):
        raise ValueError(
            f"checkpoint has {len(states)} sampler rng states, run has "
            f"{len(samplers)} samplers")
    for s, st in zip(samplers, states):
        s.rng.bit_generator.state = st


# ------------------------------------------------------------- federation
class Federation:
    """Round orchestrator over a (strategy, backend) pair.

    ``callbacks`` are called once per round with a record dict
    ``{"round", "selected", "wall_s"[, "acc"]}``. ``checkpoint_every=N``
    with ``checkpoint_dir`` writes ``round_XXXX.npz`` after every N-th
    round; ``run(resume_from=path)`` continues a run from such a file.
    """

    def __init__(self, strategy, backend, *, rounds: int,
                 eval_batch=None, eval_every: int = 1,
                 participation: Optional[Participation] = None,
                 callbacks: Sequence[Callable[[Dict[str, Any]], None]] = (),
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0):
        self.participation = participation or Participation()
        if rounds < 0:
            raise ValueError(f"rounds={rounds!r} must be >= 0")
        if eval_every < 1:
            raise ValueError(f"eval_every={eval_every!r} must be >= 1")
        self.strategy = strategy
        self.backend = backend.bind(strategy)
        self.rounds = rounds
        self.eval_batch = eval_batch
        self.eval_every = eval_every
        self.callbacks = list(callbacks)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every

    # ------------------------------------------------------------- running
    def run(self, key=None, *, resume_from: Optional[str] = None
            ) -> Dict[str, Any]:
        # re-bind: another Federation may have bound the shared backend to
        # a different strategy since construction
        self.backend.bind(self.strategy)
        key = key if key is not None else jax.random.PRNGKey(0)
        state = self.backend.init_state(key)
        start, hist = 0, []
        if resume_from is not None:
            state, extra = load_round_checkpoint(resume_from, like=state)
            start, hist = extra["round"], list(extra["history"])
            restore_sampler_rngs(self.backend.samplers, extra)
            # a compressed run's error-feedback residuals ride a sibling
            # plane file — restore them so the resumed run bit-matches
            wp = wire_checkpoint_path(resume_from)
            lw = getattr(self.backend, "load_wire_residuals", None)
            if os.path.exists(wp) and callable(lw):
                arr, _, _ = load_plane(wp)
                lw(arr)
        t0 = time.time()
        for r in range(start, self.rounds):
            selected = self.participation.select(r, self.strategy.n_clients)
            state = self.backend.run_round(state, r, selected)
            record: Dict[str, Any] = {"round": r + 1, "selected": selected,
                                      "wall_s": time.time() - t0}
            ws = getattr(self.backend, "wire_stats", None)
            wire_stats = ws() if callable(ws) else None
            if wire_stats:
                record["wire_bytes"] = wire_stats["bytes_per_round"]
            if (r + 1) % self.eval_every == 0 and self.eval_batch is not None:
                acc = self.backend.evaluate(state, r + 1, self.eval_batch)
                hist.append(acc)
                record["acc"] = acc
            for cb in self.callbacks:
                cb(record)
            if (self.checkpoint_dir and self.checkpoint_every
                    and (r + 1) % self.checkpoint_every == 0):
                path = checkpoint_path(self.checkpoint_dir, r + 1)
                save_round_checkpoint(
                    path, state,
                    round_idx=r + 1, history=hist,
                    samplers=self.backend.samplers,
                    meta={"strategy": self.strategy.name,
                          "backend": self.backend.name})
                res_fn = getattr(self.backend, "wire_residuals", None)
                res = res_fn() if callable(res_fn) else None
                if res is not None:
                    save_plane(wire_checkpoint_path(path), res,
                               self.backend.plane_spec,
                               extra={"round": r + 1,
                                      "kind": "wire_residuals"})
        self.state = state
        return self._result(state, hist, t0)

    def _result(self, state, hist, t0) -> Dict[str, Any]:
        wall = time.time() - t0   # training time only: the final catch-up
                                  # eval below must not skew benchmarks
        final_acc = hist[-1] if hist else None
        if final_acc is None and self.eval_batch is not None:
            # eval_every may exceed rounds: still report a final accuracy
            final_acc = self.backend.evaluate(state, self.rounds,
                                              self.eval_batch)
        return {"history": hist,
                "final_acc": final_acc,
                "client_params": self.backend.client_views(state,
                                                           self.rounds),
                "global_params": (state if self.strategy.kind == "global"
                                  else None),
                "wall_s": wall}
