"""Pluggable execution backends: who actually runs a federated round.

A backend takes a bound ``Strategy`` and executes ``distribute -> local
train -> collect -> aggregate`` for one round:

  * ``LoopBackend``     — the reference path: a Python loop over the
                          participating clients, each trained in its OWN
                          architecture with a per-config jitted grad fn.
                          Supports every strategy and any participation
                          subset.
  * ``UnifiedBackend``  — the cohort-parallel path: wraps
                          ``fl/engine.py``'s ``UnifiedEngine`` so the
                          whole round runs as one stacked vmapped XLA
                          program in the union architecture (shard_map
                          over the client axis when a mesh is given).
                          The round is routed through the PACKED
                          parameter plane (``core.plane``): state packs
                          to a contiguous ``(K, P)`` buffer on round
                          entry, participant gathers are row slices,
                          aggregation is one fused kernel pass, and the
                          jitted step donates the plane buffers — while
                          the Federation-facing state (init_state /
                          run_round results, checkpoints, client_views)
                          stays the tree-shaped layout the loop
                          reference owns, so the two backends remain
                          interchangeable and checkpoint-compatible.
                          Partial participation gathers the selected
                          rows of the packed cohort and draws batches
                          from the participants' samplers only, so both
                          backends consume identical data streams
                          (DESIGN.md §7). Requires aligned client batch
                          streams.

Both expose the same surface to ``Federation``:
  bind(strategy) / init_state(key) / run_round(state, r, selected) /
  evaluate(state, r, batch) / client_views(state, r) / samplers.

``unified_eligible`` is the ``engine="auto"`` rule: unified when the
strategy supports it, the cohort's embedding is segment-representable
(depth AND width heterogeneity — the old ``depth_only`` gate is gone),
and the client batch streams are guaranteed to align. Participation and
FedADP-U no longer keep the loop — both paths read coverage from
``core.aggregation``. ``unified_ineligible_reason`` names the first
failing condition so an ``engine="auto"`` fallback is diagnosable
instead of silent.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.fl.engine import UnifiedEngine
from repro.fl.strategy import METHODS, Strategy
from repro.optim import sgd


class LoopBackend:
    """Per-client reference execution (exactly the paper's protocol)."""
    name = "loop"

    def __init__(self, family, client_cfgs: Sequence, samplers: List, *,
                 local_epochs: int = 1, lr: float = 0.01,
                 momentum: float = 0.0):
        self.family = family
        self.client_cfgs = list(client_cfgs)
        self.samplers = samplers
        self.local_epochs = local_epochs
        self._opt = sgd(lr, momentum)
        self._grad_fns: Dict[str, Callable] = {}
        self.strategy: Optional[Strategy] = None

    def bind(self, strategy: Strategy) -> "LoopBackend":
        self.strategy = strategy
        return self

    # ---------------------------------------------------------- training
    def _grad_fn(self, cfg):
        if cfg.name not in self._grad_fns:
            self._grad_fns[cfg.name] = jax.jit(self.family.loss_and_grad(cfg))
        return self._grad_fns[cfg.name]

    def _local_train(self, k: int, params):
        gf = self._grad_fn(self.client_cfgs[k])
        opt_state = self._opt.init(params)   # fresh momentum every round
        step = 0
        for batch in self.samplers[k].round_batches(self.local_epochs):
            (_, _), grads = gf(params, batch)
            params, opt_state = self._opt.update(grads, opt_state, params,
                                                 step)
            step += 1
        return params

    # ----------------------------------------------------------- surface
    def init_state(self, key):
        return self.strategy.init_state(key)

    def run_round(self, state, round_idx: int, selected: Sequence[int]):
        s = self.strategy
        updates = []
        for k in selected:
            trained = self._local_train(k, s.distribute(state, round_idx, k))
            updates.append((k, s.collect(state, round_idx, k, trained)))
        return s.aggregate(state, round_idx, updates)

    def client_views(self, state, round_idx: int) -> List:
        return [self.strategy.client_view(state, k, round_idx)
                for k in range(len(self.client_cfgs))]

    def evaluate(self, state, round_idx: int, eval_batch) -> float:
        accs = [self.family.evaluate(p, c, eval_batch)
                for p, c in zip(self.client_views(state, round_idx),
                                self.client_cfgs)]
        return float(np.mean(accs))


class UnifiedBackend:
    """Cohort-parallel execution through ``UnifiedEngine`` (one stacked
    program over the packed parameter plane; loop-equivalent on
    segment-representable depth- and width-heterogeneous cohorts —
    fl/engine.py docstring)."""
    name = "unified"

    def __init__(self, family, client_cfgs: Sequence, samplers: List, *,
                 local_epochs: int = 1, lr: float = 0.01,
                 momentum: float = 0.0, use_kernel: Optional[bool] = None,
                 mesh=None, seed: int = 0, agg_layout: str = "auto",
                 k_chunk: Optional[int] = None, wire: str = "f32",
                 wire_tile: int = 256, wire_sparse: bool = False,
                 compute_dtype: str = "f32", attn_backend: str = "auto"):
        self.family = family
        self.client_cfgs = list(client_cfgs)
        self.samplers = samplers
        self.local_epochs = local_epochs
        self.lr, self.momentum = lr, momentum
        self.use_kernel, self.mesh, self.seed = use_kernel, mesh, seed
        self.agg_layout, self.k_chunk = agg_layout, k_chunk
        self.wire, self.wire_tile = wire, wire_tile
        self.wire_sparse = wire_sparse
        self.compute_dtype = compute_dtype
        self.attn_backend = attn_backend
        self.strategy: Optional[Strategy] = None
        self.engine: Optional[UnifiedEngine] = None
        self._engine_key = None

    def bind(self, strategy: Strategy) -> "UnifiedBackend":
        if strategy.name not in METHODS:
            raise ValueError(
                f"unified backend does not support {strategy.name!r}")
        self.strategy = strategy
        # aggregation weights come from the STRATEGY's n_samples (the same
        # numbers strategy.aggregate would use on the loop backend), not
        # from whatever samplers the backend currently holds
        n_samples = [int(n) for n in strategy.n_samples]
        # keep the engine (and its jitted steps) across rebinds of the SAME
        # method/coverage-knobs/weights; rebuild when the strategy's math
        # changes
        # the NetChange seed comes from the STRATEGY when it has one
        # (FedADP.base_seed — the loop derives its per-round To-Wider
        # mappings from it, so the engine must too; backend `seed` is the
        # fallback for per-client-state strategies, which only embed once)
        embed_seed = getattr(strategy, "base_seed", self.seed)
        # the aggregation layout / streaming chunk: an EXPLICIT strategy
        # setting wins (the strategy's aggregate must match the engine's),
        # otherwise the backend's knob (itself defaulting to "auto" —
        # core.aggregation.resolve_agg_layout picks per cohort shape)
        agg_layout = getattr(strategy, "agg_layout", None)
        if agg_layout in (None, "auto", "leaf"):
            # "leaf" is a loop-side reference layout; the engine has no
            # per-leaf path, so it falls through to the backend's knob
            agg_layout = self.agg_layout
        k_chunk = getattr(strategy, "k_chunk", None)
        if k_chunk is None:
            k_chunk = self.k_chunk
        # the wire format follows the same rule: a strategy that carries
        # the knobs (FedADPStrategy) wins over the backend defaults —
        # "f32" on the strategy means uncompressed only when the backend
        # agrees (backend-level wire is the deployment-wide default)
        wire = getattr(strategy, "wire", None)
        if wire in (None, "f32"):
            wire = self.wire
        wire_tile = getattr(strategy, "wire_tile", None) or self.wire_tile
        wire_sparse = (getattr(strategy, "wire_sparse", False)
                       or self.wire_sparse)
        # the local-training compute policy rides the same precedence:
        # a strategy carrying non-default knobs wins over the backend
        compute_dtype = getattr(strategy, "compute_dtype", None)
        if compute_dtype in (None, "f32"):
            compute_dtype = self.compute_dtype
        attn_backend = getattr(strategy, "attn_backend", None)
        if attn_backend in (None, "auto"):
            attn_backend = self.attn_backend
        key = (strategy.name, getattr(strategy, "filler", "zero"),
               getattr(strategy, "agg_mode", "filler"),
               getattr(strategy, "coverage", "loose"),
               getattr(strategy, "narrow_mode", "paper"), embed_seed,
               tuple(n_samples), agg_layout, k_chunk, wire, wire_tile,
               wire_sparse, compute_dtype, attn_backend)
        if self.engine is None or self._engine_key != key:
            self._engine_key = key
            self.engine = UnifiedEngine(
                self.family, self.client_cfgs, n_samples,
                lr=self.lr, momentum=self.momentum, method=strategy.name,
                filler_mode=getattr(strategy, "filler", "zero"),
                agg_mode=getattr(strategy, "agg_mode", "filler"),
                coverage=getattr(strategy, "coverage", "loose"),
                narrow_mode=getattr(strategy, "narrow_mode", "paper"),
                use_kernel=self.use_kernel, mesh=self.mesh,
                embed_seed=embed_seed, agg_layout=agg_layout,
                k_chunk=k_chunk, wire=wire, wire_tile=wire_tile,
                wire_sparse=wire_sparse, compute_dtype=compute_dtype,
                attn_backend=attn_backend)
        return self

    @property
    def plane_spec(self):
        """The engine's packed layout (``core.plane.PlaneSpec``) — the
        spec a deployment would hand to ``checkpoint.save_plane`` or a
        wire-format encoder. ``None`` before ``bind``."""
        return self.engine.plane_spec if self.engine is not None else None

    def cache_stats(self) -> Optional[dict]:
        """Embedding-artifact cache counters of the bound engine
        (``netchange.KeyedCache``)."""
        return self.engine.cache_stats() if self.engine is not None else None

    # ------------------------------------------------------- wire format
    def wire_stats(self) -> Optional[dict]:
        """Byte accounting of the engine's last compressed round (empty
        when ``wire="f32"``, None before ``bind``)."""
        return self.engine.wire_stats() if self.engine is not None else None

    def wire_residuals(self):
        """The engine's per-client error-feedback residual plane
        ``(K, P)`` f32, or None when no compressed round has run — what
        the Federation checkpoints next to the round state."""
        return (self.engine.wire_residuals() if self.engine is not None
                else None)

    def load_wire_residuals(self, arr):
        """Restore a checkpointed residual plane into the bound engine
        (the compressed-run resume path)."""
        if self.engine is None:
            raise ValueError("load_wire_residuals needs a bound engine "
                             "(Federation binds before resuming)")
        self.engine.load_wire_residuals(arr)

    # ------------------------------------------------------- batch stream
    def _stacked_round_batches(self, selected: Sequence[int]
                               ) -> List[Dict[str, np.ndarray]]:
        """Draw one round of local batches from the PARTICIPATING
        samplers and stack them on a leading axis (``selected`` order).
        Consumes the SAME rng stream per sampler as the loop path — and
        none at all for non-participants — so the two paths see identical
        data under any participation schedule."""
        per = [list(self.samplers[k].round_batches(self.local_epochs))
               for k in selected]
        counts = {len(b) for b in per}
        if len(counts) != 1:
            raise ValueError(
                "unified backend needs aligned client batch streams "
                f"(got per-client step counts {sorted(counts)}); "
                "use the loop backend for ragged cohorts")
        out = []
        for t in range(counts.pop()):
            shapes = {tuple((k, v.shape) for k, v in sorted(b[t].items()))
                      for b in per}
            if len(shapes) != 1:
                raise ValueError(
                    "unified backend needs identical batch shapes across "
                    "clients; use the loop backend")
            out.append({k: np.stack([b[t][k] for b in per])
                        for k in per[0][t]})
        return out

    # ----------------------------------------------------------- surface
    def init_state(self, key):
        if self.strategy.kind == "global":
            return self.engine.init_global(key)
        return self.engine.embed(self.strategy.init_state(key))

    def run_round(self, state, round_idx: int, selected: Sequence[int]):
        sel = list(selected)
        return self.engine.run_round(state, self._stacked_round_batches(sel),
                                     selected=sel, round_idx=round_idx)

    def client_views(self, state, round_idx: int) -> List:
        stacked = (self.engine.round_start(state, round_idx=round_idx)
                   if self.strategy.kind == "global" else state)
        return [self.engine.client_view(stacked, k)
                for k in range(len(self.client_cfgs))]

    def evaluate(self, state, round_idx: int, eval_batch) -> float:
        gcfg = self.engine.global_cfg
        accs = [self.family.evaluate(p, gcfg, eval_batch)
                for p in self.client_views(state, round_idx)]
        return float(np.mean(accs))


def unified_ineligible_reason(strategy: Strategy, family, client_cfgs,
                              samplers) -> Optional[str]:
    """Why ``engine="auto"`` would keep the loop for this run — None when
    the unified engine applies. The conditions: a unified-engine method,
    a segment-representable cohort embedding (depth and width both
    qualify; the old ``depth_only`` gate is deleted), and aligned client
    batch streams (equal n_samples + batch_size + round_fraction means
    every sampler draws the same per-round take). Neither FedADP-U nor
    partial participation keeps the loop anymore: both paths read
    coverage from ``core.aggregation`` and the engine runs
    selected-subset rounds."""
    if strategy.name not in METHODS:
        return (f"strategy {strategy.name!r} is not a unified-engine "
                f"method (supported: {', '.join(METHODS)})")
    cfgs = list(client_cfgs)
    rep = getattr(family, "segment_representable", None)
    representable = rep(cfgs) if rep is not None else family.depth_only(cfgs)
    if not representable:
        return ("cohort embedding is not segment-representable (only "
                "depth and supported width dimensions may vary — "
                "family.segment_representable)")
    if len({s.n_samples for s in samplers}) != 1:
        return ("ragged client datasets (unequal n_samples) — stacked "
                "batch streams would not align")
    if len({s.batch_size for s in samplers}) != 1:
        return "unequal client batch sizes — stacked batches must align"
    if len({getattr(s, "round_fraction", None) for s in samplers}) != 1:
        return ("unequal per-round data fractions — stacked batch "
                "streams would not align")
    return None


def unified_eligible(strategy: Strategy, family, client_cfgs,
                     samplers) -> bool:
    """The ``engine="auto"`` rule — see ``unified_ineligible_reason``."""
    return unified_ineligible_reason(strategy, family, client_cfgs,
                                     samplers) is None
