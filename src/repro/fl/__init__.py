from repro.fl.simulator import FLRunConfig, Simulator  # noqa: F401
