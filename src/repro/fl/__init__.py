from repro.fl.engine import UnifiedEngine, client_embedding  # noqa: F401
from repro.fl.simulator import FLRunConfig, Simulator  # noqa: F401
from repro.fl.unified import UnifiedFedADP  # noqa: F401
