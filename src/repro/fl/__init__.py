from repro.fl.engine import UnifiedEngine, client_embedding  # noqa: F401
from repro.fl.strategy import (  # noqa: F401
    ClusteredStrategy, FedADPStrategy, FlexiFedStrategy, StandaloneStrategy,
    Strategy, make_strategy)
from repro.fl.backends import (  # noqa: F401
    LoopBackend, UnifiedBackend, unified_eligible,
    unified_ineligible_reason)
from repro.fl.federation import (  # noqa: F401
    Federation, Participation, checkpoint_path, load_round_checkpoint,
    restore_sampler_rngs, save_round_checkpoint)
from repro.fl.simulator import FLRunConfig, Simulator  # noqa: F401
from repro.fl.unified import UnifiedFedADP  # noqa: F401
