"""Unified-space FedADP simulation — the TPU-native realization of the
paper's "transform everything into one architecture" idea (DESIGN.md §2).

Because NetChange embeds every client into the global architecture, a
heterogeneous cohort can be simulated as ONE stacked computation:

  * client k's model = the global architecture with a 0/1 structure mask
    (masked-out parameters held at zero => pre-norm residual identity),
  * local training = `jax.vmap` over the stacked (K, ...) parameters with
    mask-projected gradients — one XLA program for the whole cohort, and
    `shard_map`-able over the data axis so clients live on device shards,
  * FedAvg = `fedavg_stacked` (Pallas ``fedavg`` kernel on TPU).

Faithfulness: EXACT for depth-heterogeneous cohorts (masked blocks are
zero = the same identity filler literal FedADP produces; verified in
tests/test_unified.py). Width heterogeneity is embedded prefix-style
(mask kills column/row pairs) rather than by Alg. 2's random duplication
— a documented approximation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import client_weights, fedavg_stacked, stack_trees


@dataclass
class UnifiedFedADP:
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    loss_fn: Callable            # loss_fn(params, batch) under the GLOBAL cfg
    lr: float = 0.05
    use_kernel: bool = False

    def __post_init__(self):
        self.global_cfg = self.family.union(list(self.client_cfgs))
        self.weights = client_weights(self.n_samples)
        key = jax.random.PRNGKey(0)
        masks = []
        for cfg in self.client_cfgs:
            ones = jax.tree.map(jnp.ones_like, self.family.init(key, cfg))
            up = self.family.up(ones, cfg, self.global_cfg, seed=0)
            masks.append(jax.tree.map(
                lambda m: (jnp.abs(m) > 0).astype(jnp.float32), up))
        self.masks = stack_trees(masks)

    def init_global(self, key):
        return self.family.init(key, self.global_cfg)

    def round(self, global_params, stacked_batches: List, *, epochs: int = 1):
        """stacked_batches: list of pytrees whose leaves carry a leading K
        axis (one slice per client). One FedADP round, fully vmapped."""
        K = len(self.client_cfgs)

        start = jax.vmap(lambda m: jax.tree.map(
            lambda g, mm: g * mm, global_params, m))(self.masks)

        def one_step(params_k, mask_k, batch_k):
            g = jax.grad(self.loss_fn)(params_k, batch_k)
            return jax.tree.map(lambda p, gg, mm: p - self.lr * gg * mm,
                                params_k, g, mask_k)

        step = jax.jit(jax.vmap(one_step))
        params = start
        for _ in range(epochs):
            for batch in stacked_batches:
                params = step(params, self.masks, batch)
        w = self.weights / self.weights.sum()
        return fedavg_stacked(params, w, use_kernel=self.use_kernel)
