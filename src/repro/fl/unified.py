"""Unified-space FedADP simulation — the TPU-native realization of the
paper's "transform everything into one architecture" idea (DESIGN.md §2).

Thin FedADP-shaped facade over ``fl/engine.py``'s ``UnifiedEngine``; kept
for callers that drive rounds with pre-stacked batches and a custom
global-space loss. The engine owns the mechanics: stacked (K, ...)
parameters, mask-projected vmapped gradients, a step function jitted
once, optional ``shard_map`` over the client axis, and ``fedavg_stacked``
(Pallas kernel on TPU, auto-selected).

Faithfulness: EXACT for depth-heterogeneous cohorts (the filler is the
same identity/zero constant FedADP's ``up()`` produces; verified in
tests/test_unified.py). Width-heterogeneous cohorts run through the
engine's segment operators with per-round To-Wider mappings — pass
``round_idx`` to ``round()`` to advance them (the engine draws the same
``netchange.round_embed_seed`` mappings the loop reference would).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence

from repro.fl.engine import UnifiedEngine


@dataclass
class UnifiedFedADP:
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    loss_fn: Callable            # loss_fn(params, batch) under the GLOBAL cfg
    lr: float = 0.05
    use_kernel: Optional[bool] = None

    def __post_init__(self):
        self._engine = UnifiedEngine(
            self.family, self.client_cfgs, self.n_samples, lr=self.lr,
            momentum=0.0, method="fedadp", loss_fn=self.loss_fn,
            use_kernel=self.use_kernel)
        self.global_cfg = self._engine.global_cfg
        self.weights = self._engine.weights
        self.masks = self._engine.masks

    def init_global(self, key):
        return self._engine.init_global(key)

    def round(self, global_params, stacked_batches: List, *, epochs: int = 1,
              round_idx: int = 0):
        """stacked_batches: list of pytrees whose leaves carry a leading K
        axis (one slice per client). One FedADP round, fully vmapped —
        delegated to the engine so round start, segment-projected
        training and aggregation share one round seed."""
        return self._engine.run_round(
            global_params, [b for _ in range(epochs) for b in stacked_batches],
            round_idx=round_idx)
