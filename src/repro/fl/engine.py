"""Cohort-parallel unified FL engine (DESIGN.md §2, §5).

NetChange embeds every heterogeneous client into the cohort's union
architecture, so a whole federated round can run as ONE stacked XLA
program instead of a Python loop over clients:

  * client k's model = the global architecture with a constant *filler*
    on the parameters the client doesn't have (zero blocks for pre-norm
    residual transformers, identity convs for VGG — whatever ``up()``
    would insert) and a 0/1 *trainable mask* on the ones it does,
  * local training = ``jax.vmap`` over the stacked (K, ...) parameter
    tree with mask-projected gradients and stacked optimizer state
    (SGD + momentum from ``repro.optim``), jitted ONCE per engine,
  * the client axis is ``shard_map``-ed over a device mesh via the
    ``sharding/rules.py`` machinery (``stacked_client_spec``) — local
    training is embarrassingly parallel over K, so the shard-mapped body
    needs no collectives,
  * aggregation = ``fedavg_stacked`` (Pallas ``fedavg`` kernel on TPU,
    jnp fallback elsewhere, auto-selected).

Faithfulness (verified in tests/test_unified.py against the per-client
``LoopBackend`` reference path; ``UnifiedBackend`` in fl/backends.py is
the Federation-facing wrapper around this engine — DESIGN.md §7):

  * EXACT for depth-heterogeneous cohorts: the filler is a pointwise
    identity in the forward pass (zero block under a pre-norm residual;
    identity conv under ReLU on non-negative activations), masked
    gradients keep it constant, and aggregating the stacked tree with
    the filler in place reproduces the paper's zero/identity-filler
    FedAvg literally.
  * Width heterogeneity embeds through a FIXED To-Wider mapping
    (``embed_seed``) instead of Alg. 2's per-round random duplication —
    a documented approximation (EXPERIMENTS.md §Ablations).

Methods: ``fedadp`` (filler "zero" | "global"), ``clustered``,
``flexifed`` (VGG chain), ``standalone``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.aggregation import client_weights, fedavg_stacked, stack_trees
from repro.core.baselines import _cluster_ids
from repro.optim import sgd
from repro.sharding.rules import stacked_client_spec


def client_embedding(family, client_cfgs: Sequence, global_cfg, *,
                     seed: int = 0):
    """Stacked (masks, filler) for embedding a cohort into ``global_cfg``.

    ``up()`` is linear in the client parameters except for the constants
    it inserts (identity convs / zero blocks), so pushing an all-ones and
    an all-zeros tree through it separates the two:

      filler  = up(zeros)                 — the inserted constants,
      mask    = |up(ones) - up(zeros)| > 0 — 1 exactly where a client
                                             parameter lands.
    """
    key = jax.random.PRNGKey(0)
    masks, fillers = [], []
    for cfg in client_cfgs:
        proto = family.init(key, cfg)
        up0 = family.up(jax.tree.map(jnp.zeros_like, proto), cfg, global_cfg,
                        seed=seed)
        up1 = family.up(jax.tree.map(jnp.ones_like, proto), cfg, global_cfg,
                        seed=seed)
        masks.append(jax.tree.map(
            lambda a, b: (jnp.abs(a - b) > 0).astype(jnp.float32), up1, up0))
        fillers.append(up0)
    return stack_trees(masks), stack_trees(fillers)


@dataclass
class UnifiedEngine:
    """Runs FL methods in the stacked unified space. See module docstring."""
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    lr: float = 0.01
    momentum: float = 0.0
    method: str = "fedadp"
    filler_mode: str = "zero"            # fedadp only: "zero" | "global"
    loss_fn: Optional[Callable] = None   # loss(params, batch) under the
                                         # GLOBAL cfg; default: family's
    use_kernel: Optional[bool] = None    # None = auto (Pallas on TPU)
    mesh: Optional[Mesh] = None          # shard the client axis over this
    client_axes: Tuple[str, ...] = ("clients",)
    embed_seed: int = 0

    def __post_init__(self):
        self.global_cfg = self.family.union(list(self.client_cfgs))
        self.weights = client_weights(self.n_samples)
        self.masks, self.filler = client_embedding(
            self.family, self.client_cfgs, self.global_cfg,
            seed=self.embed_seed)
        self.clusters = _cluster_ids(self.client_cfgs)
        if self.method == "flexifed":
            self._prefix_paths = self._flexifed_prefix_paths()
        self._opt = sgd(self.lr, self.momentum)
        self._step = self._build_step()

    # ------------------------------------------------------------- step fn
    def _build_step(self):
        """One SGD step over the whole stacked cohort, jitted exactly once
        (the per-call re-``jax.jit`` of the old sketch is gone)."""
        if self.loss_fn is not None:
            lf = self.loss_fn

            def grads_one(p, b):
                return jax.grad(lf)(p, b)
        else:
            gf = self.family.loss_and_grad(self.global_cfg)

            def grads_one(p, b):
                return gf(p, b)[1]

        opt = self._opt

        def step_core(params, opt_state, masks, batch, step_idx):
            grads = jax.vmap(grads_one)(params, batch)
            grads = jax.tree.map(lambda g, m: g * m.astype(g.dtype),
                                 grads, masks)
            return opt.update(grads, opt_state, params, step_idx)

        fn = step_core
        if self.mesh is not None:
            spec = stacked_client_spec(self.mesh, self.client_axes,
                                       len(self.client_cfgs))
            if spec != P():
                # local training is independent per client: every operand
                # carries the K axis, the body needs no collectives.
                fn = shard_map(step_core, mesh=self.mesh,
                               in_specs=(spec, spec, spec, spec, P()),
                               out_specs=(spec, spec), check_rep=False)
        return jax.jit(fn)

    # ----------------------------------------------------------- embedding
    def init_global(self, key):
        return self.family.init(key, self.global_cfg)

    def round_start(self, global_params):
        """Stacked per-client views of a global model: the unified-space
        equivalent of FedADP's distribute (To-Shallower/To-Narrower)."""
        return jax.tree.map(
            lambda g, m, f: (g[None] * m + f * (1 - m)).astype(g.dtype),
            global_params, self.masks, self.filler)

    def embed(self, client_params: Sequence):
        """Stack per-client (client-space) trees into the unified space."""
        return stack_trees([
            self.family.up(p, cfg, self.global_cfg, seed=self.embed_seed)
            for p, cfg in zip(client_params, self.client_cfgs)])

    def client_view(self, stacked, k: int):
        return jax.tree.map(lambda x: x[k], stacked)

    # ------------------------------------------------------------ training
    def train_round(self, stacked, stacked_batches: Sequence):
        """Run one local-training round: fresh optimizer state (matching
        the per-client loop, which re-inits SGD momentum every round), one
        step per stacked batch."""
        opt_state = self._opt.init(stacked)
        for i, batch in enumerate(stacked_batches):
            stacked, opt_state = self._step(
                stacked, opt_state, self.masks, batch,
                jnp.asarray(i, jnp.int32))
        return stacked

    # --------------------------------------------------------- aggregation
    def _norm_w(self, ids) -> np.ndarray:
        return client_weights(np.asarray(self.n_samples)[np.asarray(ids)])

    def aggregate_global(self, stacked, global_params=None):
        """FedADP Eq. 1-2 over the stacked tree. filler_mode="zero" keeps
        the filler constants in the average (the paper's rule — exactly
        what averaging ``up()`` outputs does); "global" (FedADP-U)
        substitutes the server's current values in uncovered regions.

        Note: for "global" this engine treats EVERY coordinate the client
        doesn't own as uncovered — including the nonzero taps of identity
        -conv filler — whereas the loop path's ``|collect(ones)| > 0``
        mask counts those taps as covered and keeps the identity values.
        The two therefore differ on VGG depth cohorts under FedADP-U
        (engine semantics are the stricter reading); ``engine="auto"``
        keeps FedADP-U on the loop path for this reason."""
        if self.filler_mode == "global":
            assert global_params is not None
            stacked = jax.tree.map(
                lambda p, m, g: p * m + g[None] * (1 - m),
                stacked, self.masks, global_params)
        return fedavg_stacked(stacked, self.weights,
                              use_kernel=self.use_kernel)

    def _agg_clustered(self, stacked):
        new = stacked
        for ids in self.clusters.values():
            idx = jnp.asarray(ids)
            sub = jax.tree.map(lambda x: x[idx], stacked)
            agg = fedavg_stacked(sub, self._norm_w(ids),
                                 use_kernel=self.use_kernel)
            new = jax.tree.map(
                lambda n, a: n.at[idx].set(
                    jnp.broadcast_to(a[None], (len(ids),) + a.shape)),
                new, agg)
        return new

    def _flexifed_prefix_paths(self):
        """Chain positions shared by the WHOLE cohort (same layer id) —
        FlexiFed's common prefix, computed from configs alone."""
        chains = [self.family.chain_paths(c) for c in self.client_cfgs]
        n = 0
        for pos in range(min(len(c) for c in chains)):
            if len({c[pos][0] for c in chains}) == 1:
                n += 1
            else:
                break
        gchain = self.family.chain_paths(self.global_cfg)
        return {gchain[p][1] for p in range(n)}

    def _agg_flexifed(self, stacked):
        """Common prefix averaged over ALL clients, remainder within
        same-architecture clusters (Clustered-Common)."""
        glob = fedavg_stacked(stacked,
                              self._norm_w(range(len(self.n_samples))),
                              use_kernel=self.use_kernel)
        clus = self._agg_clustered(stacked)
        prefix = self._prefix_paths

        def pick(path, g, c):
            keys = tuple(str(getattr(p, "key", p)) for p in path)
            if any(keys[:len(pp)] == pp for pp in prefix):
                return jnp.broadcast_to(g[None], c.shape)
            return c
        return jax.tree_util.tree_map_with_path(pick, glob, clus)

    # ---------------------------------------------------------- full round
    def run_round(self, state, stacked_batches: Sequence):
        """One federated round. ``state`` is the global tree for fedadp
        and the stacked client tree for the per-client-parameter methods;
        returns the same kind."""
        if self.method == "fedadp":
            trained = self.train_round(self.round_start(state),
                                       stacked_batches)
            return self.aggregate_global(trained, state)
        trained = self.train_round(state, stacked_batches)
        if self.method == "clustered":
            return self._agg_clustered(trained)
        if self.method == "flexifed":
            return self._agg_flexifed(trained)
        if self.method == "standalone":
            return trained
        raise ValueError(self.method)
