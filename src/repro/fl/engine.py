"""Cohort-parallel unified FL engine (DESIGN.md §2, §5).

NetChange embeds every heterogeneous client into the cohort's union
architecture, so a whole federated round can run as ONE stacked XLA
program instead of a Python loop over clients:

  * client k's model = the global architecture with a constant *filler*
    on the parameters the client doesn't have (zero blocks for pre-norm
    residual transformers, identity convs for VGG — whatever ``up()``
    would insert) and a 0/1 *trainable mask* on the ones it does; width
    heterogeneity adds the *segment operators* of ``core.segments``:
    ``up()`` is linear (``u = E p + filler``), E duplicates client
    channels into union segments,
  * local training = ``jax.vmap`` over the stacked (K, ...) parameter
    tree with gradients transformed by ``E Eᵀ`` (per-axis segment sums,
    1/c² on Net2Net split axes) then mask-projected — exactly the
    pushforward of the client-shape gradient, so union-space SGD(+
    momentum, from ``repro.optim``) *equals* client-shape SGD: the
    stacked state stays ``E p_k`` throughout. Jitted ONCE per engine and
    participating-subset size,
  * the client axis is ``shard_map``-ed over a device mesh via the
    ``sharding/rules.py`` machinery (``stacked_client_spec``) — local
    training is embarrassingly parallel over K, so the shard-mapped body
    needs no collectives,
  * aggregation = ``fedavg_stacked`` (Pallas ``fedavg`` kernels on TPU,
    jnp fallback elsewhere, auto-selected), with the coverage semantics
    single-sourced in ``core.aggregation``: the strict mask is the
    trainable-coordinate projection, the ``coverage`` policy (default
    "loose", the loop reference's reading) decides what counts as
    covered during aggregation, and ``agg_mode="coverage"`` switches
    Eq. 1's filler-polluted average for the HeteroFL-style renormalized
    average over covering clients — multiplicity-aware on width cohorts
    (per-coordinate weight W_k/m_k, same single kernel pass).

Partial participation: ``run_round(state, batches, selected=...)`` runs
the round on the gathered ``selected`` slice of the stacked tree —
weights/masks renormalize over the subset, per-client state scatters
back, cluster/prefix aggregation intersects with the participants — so
the engine supports every participation schedule the loop reference
does, bit-compatibly on its exact domain.

Faithfulness (verified in tests/test_unified.py + tests/test_federation.py
against the per-client ``LoopBackend`` reference path; ``UnifiedBackend``
in fl/backends.py is the Federation-facing wrapper around this engine —
DESIGN.md §7):

  * EXACT for depth-heterogeneous cohorts: the filler is a pointwise
    identity in the forward pass (zero block under a pre-norm residual;
    identity conv under ReLU on non-negative activations), masked
    gradients keep it constant, and aggregating the stacked tree with
    the filler in place reproduces the paper's zero/identity-filler
    FedAvg literally.
  * EXACT (to float tolerance) for width-heterogeneous cohorts whose
    embedding is segment-representable (``family.segment_representable``
    — the old ``depth_only`` gate is gone): fedadp rounds draw the SAME
    per-(round, client) To-Wider mappings as the loop
    (``netchange.round_embed_seed``), round start is the literal
    ``up(down(·))`` under the strategy's ``narrow_mode``, training keeps
    the stack in image(E) via the segment-projected gradients, and both
    paths read coverage + multiplicity from ``core.aggregation``.
    Per-client-state methods embed once at the fixed ``embed_seed`` (so
    same-architecture clients share one mapping and cluster/prefix
    averages commute with E).

Methods: ``fedadp`` (filler "zero" | "global"), ``clustered``,
``flexifed`` (VGG chain), ``standalone``.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import segments as sg
from repro.core.aggregation import (AGG_MODES, COVERAGE_POLICIES,
                                    client_weights, coverage_and_filler,
                                    fedavg_stacked, global_shapes, loosen,
                                    stack_trees, subset_weights)
from repro.core.baselines import _cluster_ids
from repro.core.netchange import NARROW_MODES, round_embed_seed, seed_lru
from repro.optim import sgd
from repro.sharding.rules import stacked_client_spec


def client_embedding(family, client_cfgs: Sequence, global_cfg, *,
                     seed: int = 0):
    """Stacked (strict masks, filler) for embedding a cohort into
    ``global_cfg`` — per-client trees from
    ``core.aggregation.coverage_and_filler``, stacked on a leading K
    axis."""
    masks, fillers = [], []
    for cfg in client_cfgs:
        m, f = coverage_and_filler(family, cfg, global_cfg, seed=seed)
        masks.append(m)
        fillers.append(f)
    return stack_trees(masks), stack_trees(fillers)


@dataclass
class UnifiedEngine:
    """Runs FL methods in the stacked unified space. See module docstring."""
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    lr: float = 0.01
    momentum: float = 0.0
    method: str = "fedadp"
    filler_mode: str = "zero"            # fedadp only: "zero" | "global"
    agg_mode: str = "filler"             # "filler" (Eq. 1) | "coverage"
    coverage: str = "loose"              # what counts as covered when
                                         # aggregating (core.aggregation)
    narrow_mode: str = "paper"           # fedadp distribute: Alg. 3 | fold
    loss_fn: Optional[Callable] = None   # loss(params, batch) under the
                                         # GLOBAL cfg; default: family's
    use_kernel: Optional[bool] = None    # None = auto (Pallas on TPU)
    mesh: Optional[Mesh] = None          # shard the client axis over this
    client_axes: Tuple[str, ...] = ("clients",)
    embed_seed: int = 0                  # base NetChange seed; fedadp
                                         # rounds derive per-(round, k)
                                         # seeds from it (round_embed_seed)

    def __post_init__(self):
        if self.agg_mode not in AGG_MODES:
            raise ValueError(f"agg_mode={self.agg_mode!r}, expected one of "
                             f"{AGG_MODES}")
        if self.coverage not in COVERAGE_POLICIES:
            raise ValueError(f"coverage={self.coverage!r}, expected one of "
                             f"{COVERAGE_POLICIES}")
        if self.narrow_mode not in NARROW_MODES:
            raise ValueError(f"narrow_mode={self.narrow_mode!r}, expected "
                             f"one of {NARROW_MODES}")
        self.global_cfg = self.family.union(list(self.client_cfgs))
        self.weights = client_weights(self.n_samples)
        self._depth_only = self.family.depth_only(list(self.client_cfgs))
        if not self._depth_only:
            rep = getattr(self.family, "segment_representable", None)
            if rep is None or not rep(list(self.client_cfgs)):
                raise ValueError(
                    "unified engine needs a depth-only or segment-"
                    "representable cohort (family.segment_representable); "
                    "use the loop backend for this cohort")
        self._gshapes = global_shapes(self.family, self.global_cfg)
        # the static segment structure (which leaves/axes are widened) is
        # seed-invariant — only the matrix VALUES change per round seed
        if self._depth_only:
            self._axes_map: Dict = {}
        else:
            specs = [self.family.segment_spec(cfg, self.global_cfg,
                                              seed=self.embed_seed)
                     for cfg in self.client_cfgs]
            self._axes_map = sg.union_axes(specs, self._gshapes)
        self._seg_axes = {"/".join(p): a for p, a in self._axes_map.items()}
        self._mask_cache: Dict[int, Tuple] = {}        # per k: seed-invariant
        self._seg_cache: OrderedDict = OrderedDict()   # per (k, seed)
        self._cov_cache: OrderedDict = OrderedDict()   # per (k, seed)
        # fixed-seed cohort embedding: per-client-state methods live here
        # permanently; for fedadp it is the depth-only fast path (where
        # the embedding is seed-invariant anyway). The strict mask (and
        # with it the strict coverage reading) is seed-invariant even on
        # width cohorts — To-Wider lands a client parameter on EVERY
        # union channel of a widened axis no matter the mapping.
        trip = [self._client_mask(k) for k in range(len(self.client_cfgs))]
        self.masks = stack_trees([t[0] for t in trip])
        self.filler = stack_trees([t[1] for t in trip])
        self.cov_masks = stack_trees([t[2] for t in trip])
        if self._depth_only:
            self._seg_mats0: Dict = {}
            self._mult0 = None
        else:
            segs = [self._client_seg(k, self.embed_seed)
                    for k in range(len(self.client_cfgs))]
            self._seg_mats0 = sg.stack_matrices([s[0] for s in segs])
            self._mult0 = stack_trees([s[1] for s in segs])
        self.clusters = _cluster_ids(self.client_cfgs)
        if self.method == "flexifed":
            full = tuple(range(len(self.client_cfgs)))
            self._prefix_cache: Dict[Tuple[int, ...], set] = {}
            self._prefix_paths = self._prefix_for(full)
        self._opt = sgd(self.lr, self.momentum)
        self._steps: Dict[int, Callable] = {}

    # ----------------------------------------------------------- embedding
    def _lru(self, cache: OrderedDict, key, build):
        return seed_lru(cache, key, build, n_clients=len(self.client_cfgs))

    def _client_mask(self, k: int):
        """(strict mask, filler, cov) at the fixed ``embed_seed`` — the
        strict mask is seed-invariant always; filler and the loose cov
        reading are seed-invariant on depth-only cohorts (the only place
        the fixed filler/cov are used for fedadp)."""
        if k not in self._mask_cache:
            mask, filler = coverage_and_filler(
                self.family, self.client_cfgs[k], self.global_cfg,
                seed=self.embed_seed)
            cov = mask if self.coverage == "strict" else loosen(mask, filler)
            self._mask_cache[k] = (mask, filler, cov)
        return self._mask_cache[k]

    def _client_seg(self, k: int, seed: int):
        """(E Eᵀ matrices, multiplicity tree) for client k at one seed —
        plain numpy from ``segment_spec``, no jnp pushes; bounded LRU."""
        def build():
            spec = self.family.segment_spec(self.client_cfgs[k],
                                            self.global_cfg, seed=seed)
            return (sg.client_matrices(spec, self._axes_map, self._gshapes,
                                       kind="grad"),
                    sg.multiplicity_tree(spec, self._gshapes))
        return self._lru(self._seg_cache, (k, seed), build)

    def _client_cov(self, k: int, seed: int):
        """Aggregation-coverage mask at a round seed. Strict = the
        seed-invariant trainable mask; loose needs the round's filler
        (widened identity-conv taps move with the mapping) — one extra
        pair of ``up`` pushes per (client, seed), cached."""
        if self._depth_only or self.coverage == "strict":
            return self._client_mask(k)[2]

        def build():
            mask, filler = coverage_and_filler(
                self.family, self.client_cfgs[k], self.global_cfg, seed=seed)
            return loosen(mask, filler)
        return self._lru(self._cov_cache, (k, seed), build)

    def _round_seed(self, round_idx: int, k: int) -> int:
        return round_embed_seed(self.embed_seed, round_idx, k)

    # ------------------------------------------------------------- step fn
    def _step_for(self, k_count: int):
        """The stacked SGD step for a cohort (or participating subset) of
        ``k_count`` clients — jitted exactly once per subset size."""
        if k_count not in self._steps:
            self._steps[k_count] = self._build_step(k_count)
        return self._steps[k_count]

    def _build_step(self, k_count: int):
        if self.loss_fn is not None:
            lf = self.loss_fn

            def grads_one(p, b):
                return jax.grad(lf)(p, b)
        else:
            gf = self.family.loss_and_grad(self.global_cfg)

            def grads_one(p, b):
                return gf(p, b)[1]

        opt = self._opt
        seg_axes = self._seg_axes

        def step_core(params, opt_state, masks, seg_mats, batch, step_idx):
            grads = jax.vmap(grads_one)(params, batch)
            # width: E Eᵀ per leaf keeps the update in image(E) and equal
            # to the client-shape SGD step; depth: the 0/1 mask keeps the
            # filler constant. The two commute (masks are constant along
            # segment axes).
            grads = sg.project_stacked(grads, seg_axes, seg_mats)
            grads = jax.tree.map(lambda g, m: g * m.astype(g.dtype),
                                 grads, masks)
            return opt.update(grads, opt_state, params, step_idx)

        fn = step_core
        if self.mesh is not None:
            spec = stacked_client_spec(self.mesh, self.client_axes, k_count)
            if spec != P():
                # local training is independent per client: every operand
                # carries the K axis, the body needs no collectives.
                fn = shard_map(step_core, mesh=self.mesh,
                               in_specs=(spec, spec, spec, spec, spec, P()),
                               out_specs=(spec, spec), check_rep=False)
        return jax.jit(fn)

    # ------------------------------------------------------------- subsets
    def _resolve(self, selected) -> Optional[list]:
        """None = full participation; otherwise the participating subset."""
        if selected is None:
            return None
        sel = list(selected)
        return None if sel == list(range(len(self.client_cfgs))) else sel

    @staticmethod
    def _gather(tree, selected):
        if selected is None:
            return tree
        idx = jnp.asarray(selected)
        return jax.tree.map(lambda x: x[idx], tree)

    @staticmethod
    def _scatter(tree, selected, sub):
        if selected is None:
            return sub
        idx = jnp.asarray(selected)
        return jax.tree.map(lambda t, s: t.at[idx].set(s), tree, sub)

    # ----------------------------------------------------------- embedding
    def init_global(self, key):
        return self.family.init(key, self.global_cfg)

    def round_start(self, global_params, selected=None, round_idx: int = 0):
        """Stacked per-client views of a global model: the unified-space
        equivalent of FedADP's distribute (To-Shallower/To-Narrower),
        restricted to the participating subset when given. Depth-only
        cohorts use the fused mask/filler arithmetic (``up(down(g))`` is
        literally ``g·m + f·(1−m)`` there); width cohorts run the
        literal per-client ``up(down(g))`` at the round's seeds under
        ``narrow_mode`` — the same NetChange work the loop's distribute
        + collect would do, with training still stacked."""
        if self._depth_only:
            masks = self._gather(self.masks, selected)
            filler = self._gather(self.filler, selected)
            return jax.tree.map(
                lambda g, m, f: (g[None] * m + f * (1 - m)).astype(g.dtype),
                global_params, masks, filler)
        ks = (list(range(len(self.client_cfgs))) if selected is None
              else list(selected))
        views = []
        for k in ks:
            s = self._round_seed(round_idx, k)
            down = self.family.down(global_params, self.global_cfg,
                                    self.client_cfgs[k], seed=s,
                                    mode=self.narrow_mode)
            views.append(self.family.up(down, self.client_cfgs[k],
                                        self.global_cfg, seed=s))
        return stack_trees(views)

    def embed(self, client_params: Sequence):
        """Stack per-client (client-space) trees into the unified space
        at the FIXED ``embed_seed`` — the per-client-state layout, where
        same-architecture clients must share one mapping so cluster and
        prefix averages commute with the embedding."""
        return stack_trees([
            self.family.up(p, cfg, self.global_cfg, seed=self.embed_seed)
            for p, cfg in zip(client_params, self.client_cfgs)])

    def client_view(self, stacked, k: int):
        return jax.tree.map(lambda x: x[k], stacked)

    # ------------------------------------------------------------ training
    def train_round(self, stacked, stacked_batches: Sequence, *, masks=None,
                    seg_mats=None):
        """Run one local-training round: fresh optimizer state (matching
        the per-client loop, which re-inits SGD momentum every round), one
        step per stacked batch. ``masks``/``seg_mats`` default to the
        fixed-seed full-cohort embedding; pass gathered/per-round values
        for partial or fedadp width rounds."""
        masks = self.masks if masks is None else masks
        seg_mats = self._seg_mats0 if seg_mats is None else seg_mats
        step = self._step_for(jax.tree.leaves(masks)[0].shape[0])
        opt_state = self._opt.init(stacked)
        for i, batch in enumerate(stacked_batches):
            stacked, opt_state = step(
                stacked, opt_state, masks, seg_mats, batch,
                jnp.asarray(i, jnp.int32))
        return stacked

    # --------------------------------------------------------- aggregation
    def aggregate_global(self, stacked, global_params=None, selected=None,
                         *, cov=None, mult=None):
        """FedADP Eq. 1-2 over the (sub-)stacked tree, weights
        renormalized over the participating subset.

        ``agg_mode="filler"``: filler_mode="zero" keeps the filler
        constants in the average (the paper's rule — exactly what
        averaging ``up()`` outputs does); "global" (FedADP-U) substitutes
        the server's current values on UNCOVERED coordinates, where
        covered is read from ``core.aggregation.coverage_mask`` under the
        engine's ``coverage`` policy — the same mask the loop reference
        uses, so the two paths agree by construction.

        ``agg_mode="coverage"``: the HeteroFL-style average — each
        coordinate over only the clients that cover it, per-coordinate
        weight renormalization (multiplicity-aware on width cohorts:
        W_k/m_k per duplicated coordinate), server values where no
        participant covers.

        ``cov``/``mult`` override the fixed-seed embedding's masks for
        per-round-seeded fedadp width rounds.
        """
        w = subset_weights(self.n_samples, selected)
        if self.agg_mode == "coverage":
            assert global_params is not None, \
                'agg_mode="coverage" needs the current global params'
            if cov is None:
                cov = self._gather(self.cov_masks, selected)
            if mult is None and self._mult0 is not None:
                mult = self._gather(self._mult0, selected)
            return fedavg_stacked(stacked, w, masks=cov, mult=mult,
                                  renorm=True, fallback=global_params,
                                  use_kernel=self.use_kernel)
        if self.filler_mode == "global":
            assert global_params is not None
            if cov is None:
                cov = self._gather(self.cov_masks, selected)
            stacked = jax.tree.map(
                lambda p, m, g: p * m + g[None] * (1 - m),
                stacked, cov, global_params)
        return fedavg_stacked(stacked, w, use_kernel=self.use_kernel)

    def _agg_clustered(self, stacked, selected=None):
        sel = (set(range(len(self.client_cfgs))) if selected is None
               else set(selected))
        new = stacked
        for ids in self.clusters.values():
            ids = [i for i in ids if i in sel]
            if not ids:
                continue
            idx = jnp.asarray(ids)
            sub = jax.tree.map(lambda x: x[idx], stacked)
            agg = fedavg_stacked(sub, subset_weights(self.n_samples, ids),
                                 use_kernel=self.use_kernel)
            new = jax.tree.map(
                lambda n, a: n.at[idx].set(
                    jnp.broadcast_to(a[None], (len(ids),) + a.shape)),
                new, agg)
        return new

    def _flexifed_prefix_paths(self, sel):
        """Chain positions shared by the WHOLE participating subset (same
        layer id) — FlexiFed's common prefix, computed from configs
        alone. The tree paths come from the CLIENTS' chains (identical
        across the subset wherever the ids agree, and preserved by the
        front-aligned embedding); indexing into the union's chain instead
        would mis-map whenever the subset's prefix extends beyond the
        full cohort's. Layer ids carry widths, so the prefix stops at
        the first width divergence; on the prefix every participant's
        embedding is the same operator (same tag/widths/fixed seed), so
        averaging embedded prefixes equals embedding the averaged
        prefix."""
        chains = [self.family.chain_paths(self.client_cfgs[i]) for i in sel]
        paths = set()
        for pos in range(min(len(c) for c in chains)):
            if len({c[pos][0] for c in chains}) == 1:
                paths.add(chains[0][pos][1])
            else:
                break
        return paths

    def _prefix_for(self, sel) -> set:
        key = tuple(sel)
        if key not in self._prefix_cache:
            self._prefix_cache[key] = self._flexifed_prefix_paths(sel)
        return self._prefix_cache[key]

    def _agg_flexifed(self, stacked, selected=None):
        """Common prefix averaged over the PARTICIPANTS, remainder within
        (same-architecture cluster ∩ participants) — Clustered-Common.
        Non-participants keep their parameters."""
        sel = (list(range(len(self.client_cfgs))) if selected is None
               else list(selected))
        idx = jnp.asarray(sel)
        glob = fedavg_stacked(jax.tree.map(lambda x: x[idx], stacked),
                              subset_weights(self.n_samples, sel),
                              use_kernel=self.use_kernel)
        clus = self._agg_clustered(stacked, sel)
        prefix = self._prefix_for(sel)

        def pick(path, g, c):
            keys = tuple(str(getattr(p, "key", p)) for p in path)
            if any(keys[:len(pp)] == pp for pp in prefix):
                return c.at[idx].set(
                    jnp.broadcast_to(g[None], (len(sel),) + g.shape))
            return c
        return jax.tree_util.tree_map_with_path(pick, glob, clus)

    # ---------------------------------------------------------- full round
    def run_round(self, state, stacked_batches: Sequence, selected=None,
                  round_idx: int = 0):
        """One federated round over the participating subset (default:
        full cohort). ``state`` is the global tree for fedadp and the
        stacked client tree for the per-client-parameter methods; returns
        the same kind. ``stacked_batches`` leaves carry a leading axis of
        ``len(selected)`` (participants only, in ``selected`` order).
        ``round_idx`` seeds fedadp's per-round To-Wider mappings (the
        loop's ``FedADP._seed`` numbers — identical on both paths)."""
        sel = self._resolve(selected)
        if self.method == "fedadp":
            if self._depth_only:
                # round_start's body with the already-gathered masks (one
                # gather of the union-sized mask tree per round, not two)
                masks = self._gather(self.masks, sel)
                filler = self._gather(self.filler, sel)
                start = jax.tree.map(
                    lambda g, m, f: (g[None] * m + f * (1 - m)).astype(g.dtype),
                    state, masks, filler)
                trained = self.train_round(start, stacked_batches,
                                           masks=masks, seg_mats={})
                return self.aggregate_global(trained, state, selected=sel)
            ks = (list(range(len(self.client_cfgs))) if sel is None else sel)
            seeds = [self._round_seed(round_idx, k) for k in ks]
            segs = [self._client_seg(k, s) for k, s in zip(ks, seeds)]
            masks = self._gather(self.masks, sel)     # seed-invariant
            seg_mats = sg.stack_matrices([s[0] for s in segs])
            start = self.round_start(state, sel, round_idx)
            trained = self.train_round(start, stacked_batches, masks=masks,
                                       seg_mats=seg_mats)
            need_cov = (self.agg_mode == "coverage"
                        or self.filler_mode == "global")
            cov = (stack_trees([self._client_cov(k, s)
                                for k, s in zip(ks, seeds)])
                   if need_cov else None)
            mult = (stack_trees([s[1] for s in segs])
                    if self.agg_mode == "coverage" else None)
            return self.aggregate_global(trained, state, selected=sel,
                                         cov=cov, mult=mult)
        masks = self._gather(self.masks, sel)
        seg_mats = self._gather(self._seg_mats0, sel)
        trained = self.train_round(self._gather(state, sel),
                                   stacked_batches, masks=masks,
                                   seg_mats=seg_mats)
        new = self._scatter(state, sel, trained)
        if self.method == "clustered":
            return self._agg_clustered(new, sel)
        if self.method == "flexifed":
            return self._agg_flexifed(new, sel)
        if self.method == "standalone":
            return new
        raise ValueError(self.method)
