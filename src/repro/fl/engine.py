"""Cohort-parallel unified FL engine (DESIGN.md §2, §5).

NetChange embeds every heterogeneous client into the cohort's union
architecture, so a whole federated round can run as ONE stacked XLA
program instead of a Python loop over clients:

  * client k's model = the global architecture with a constant *filler*
    on the parameters the client doesn't have (zero blocks for pre-norm
    residual transformers, identity convs for VGG — whatever ``up()``
    would insert) and a 0/1 *trainable mask* on the ones it does,
  * local training = ``jax.vmap`` over the stacked (K, ...) parameter
    tree with mask-projected gradients and stacked optimizer state
    (SGD + momentum from ``repro.optim``), jitted ONCE per engine and
    participating-subset size,
  * the client axis is ``shard_map``-ed over a device mesh via the
    ``sharding/rules.py`` machinery (``stacked_client_spec``) — local
    training is embarrassingly parallel over K, so the shard-mapped body
    needs no collectives,
  * aggregation = ``fedavg_stacked`` (Pallas ``fedavg`` kernels on TPU,
    jnp fallback elsewhere, auto-selected), with the coverage semantics
    single-sourced in ``core.aggregation``: the strict mask is the
    trainable-coordinate projection, the ``coverage`` policy (default
    "loose", the loop reference's reading) decides what counts as
    covered during aggregation, and ``agg_mode="coverage"`` switches
    Eq. 1's filler-polluted average for the HeteroFL-style renormalized
    average over covering clients.

Partial participation: ``run_round(state, batches, selected=...)`` runs
the round on the gathered ``selected`` slice of the stacked tree —
weights/masks renormalize over the subset, per-client state scatters
back, cluster/prefix aggregation intersects with the participants — so
the engine supports every participation schedule the loop reference
does, bit-compatibly on its exact domain.

Faithfulness (verified in tests/test_unified.py + tests/test_federation.py
against the per-client ``LoopBackend`` reference path; ``UnifiedBackend``
in fl/backends.py is the Federation-facing wrapper around this engine —
DESIGN.md §7):

  * EXACT for depth-heterogeneous cohorts: the filler is a pointwise
    identity in the forward pass (zero block under a pre-norm residual;
    identity conv under ReLU on non-negative activations), masked
    gradients keep it constant, and aggregating the stacked tree with
    the filler in place reproduces the paper's zero/identity-filler
    FedAvg literally; both paths read coverage from
    ``core.aggregation.coverage_mask``, so FedADP-U and coverage-mode
    aggregation match the loop too.
  * Width heterogeneity embeds through a FIXED To-Wider mapping
    (``embed_seed``) instead of Alg. 2's per-round random duplication —
    a documented approximation (EXPERIMENTS.md §Ablations).

Methods: ``fedadp`` (filler "zero" | "global"), ``clustered``,
``flexifed`` (VGG chain), ``standalone``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.aggregation import (AGG_MODES, COVERAGE_POLICIES,
                                    client_weights, coverage_and_filler,
                                    fedavg_stacked, loosen, stack_trees,
                                    subset_weights)
from repro.core.baselines import _cluster_ids
from repro.optim import sgd
from repro.sharding.rules import stacked_client_spec


def client_embedding(family, client_cfgs: Sequence, global_cfg, *,
                     seed: int = 0):
    """Stacked (strict masks, filler) for embedding a cohort into
    ``global_cfg`` — per-client trees from
    ``core.aggregation.coverage_and_filler``, stacked on a leading K
    axis."""
    masks, fillers = [], []
    for cfg in client_cfgs:
        m, f = coverage_and_filler(family, cfg, global_cfg, seed=seed)
        masks.append(m)
        fillers.append(f)
    return stack_trees(masks), stack_trees(fillers)


@dataclass
class UnifiedEngine:
    """Runs FL methods in the stacked unified space. See module docstring."""
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    lr: float = 0.01
    momentum: float = 0.0
    method: str = "fedadp"
    filler_mode: str = "zero"            # fedadp only: "zero" | "global"
    agg_mode: str = "filler"             # "filler" (Eq. 1) | "coverage"
    coverage: str = "loose"              # what counts as covered when
                                         # aggregating (core.aggregation)
    loss_fn: Optional[Callable] = None   # loss(params, batch) under the
                                         # GLOBAL cfg; default: family's
    use_kernel: Optional[bool] = None    # None = auto (Pallas on TPU)
    mesh: Optional[Mesh] = None          # shard the client axis over this
    client_axes: Tuple[str, ...] = ("clients",)
    embed_seed: int = 0

    def __post_init__(self):
        if self.agg_mode not in AGG_MODES:
            raise ValueError(f"agg_mode={self.agg_mode!r}, expected one of "
                             f"{AGG_MODES}")
        if self.coverage not in COVERAGE_POLICIES:
            raise ValueError(f"coverage={self.coverage!r}, expected one of "
                             f"{COVERAGE_POLICIES}")
        self.global_cfg = self.family.union(list(self.client_cfgs))
        self.weights = client_weights(self.n_samples)
        self.masks, self.filler = client_embedding(
            self.family, self.client_cfgs, self.global_cfg,
            seed=self.embed_seed)
        # aggregation-time coverage under the configured policy: strict is
        # the trainable mask itself, loose adds the nonzero filler taps
        self.cov_masks = (self.masks if self.coverage == "strict"
                          else loosen(self.masks, self.filler))
        self.clusters = _cluster_ids(self.client_cfgs)
        if self.method == "flexifed":
            full = tuple(range(len(self.client_cfgs)))
            self._prefix_cache: Dict[Tuple[int, ...], set] = {}
            self._prefix_paths = self._prefix_for(full)
        self._opt = sgd(self.lr, self.momentum)
        self._steps: Dict[int, Callable] = {}

    # ------------------------------------------------------------- step fn
    def _step_for(self, k_count: int):
        """The stacked SGD step for a cohort (or participating subset) of
        ``k_count`` clients — jitted exactly once per subset size."""
        if k_count not in self._steps:
            self._steps[k_count] = self._build_step(k_count)
        return self._steps[k_count]

    def _build_step(self, k_count: int):
        if self.loss_fn is not None:
            lf = self.loss_fn

            def grads_one(p, b):
                return jax.grad(lf)(p, b)
        else:
            gf = self.family.loss_and_grad(self.global_cfg)

            def grads_one(p, b):
                return gf(p, b)[1]

        opt = self._opt

        def step_core(params, opt_state, masks, batch, step_idx):
            grads = jax.vmap(grads_one)(params, batch)
            grads = jax.tree.map(lambda g, m: g * m.astype(g.dtype),
                                 grads, masks)
            return opt.update(grads, opt_state, params, step_idx)

        fn = step_core
        if self.mesh is not None:
            spec = stacked_client_spec(self.mesh, self.client_axes, k_count)
            if spec != P():
                # local training is independent per client: every operand
                # carries the K axis, the body needs no collectives.
                fn = shard_map(step_core, mesh=self.mesh,
                               in_specs=(spec, spec, spec, spec, P()),
                               out_specs=(spec, spec), check_rep=False)
        return jax.jit(fn)

    # ------------------------------------------------------------- subsets
    def _resolve(self, selected) -> Optional[list]:
        """None = full participation; otherwise the participating subset."""
        if selected is None:
            return None
        sel = list(selected)
        return None if sel == list(range(len(self.client_cfgs))) else sel

    @staticmethod
    def _gather(tree, selected):
        if selected is None:
            return tree
        idx = jnp.asarray(selected)
        return jax.tree.map(lambda x: x[idx], tree)

    @staticmethod
    def _scatter(tree, selected, sub):
        if selected is None:
            return sub
        idx = jnp.asarray(selected)
        return jax.tree.map(lambda t, s: t.at[idx].set(s), tree, sub)

    # ----------------------------------------------------------- embedding
    def init_global(self, key):
        return self.family.init(key, self.global_cfg)

    def round_start(self, global_params, selected=None):
        """Stacked per-client views of a global model: the unified-space
        equivalent of FedADP's distribute (To-Shallower/To-Narrower),
        restricted to the participating subset when given."""
        masks = self._gather(self.masks, selected)
        filler = self._gather(self.filler, selected)
        return jax.tree.map(
            lambda g, m, f: (g[None] * m + f * (1 - m)).astype(g.dtype),
            global_params, masks, filler)

    def embed(self, client_params: Sequence):
        """Stack per-client (client-space) trees into the unified space."""
        return stack_trees([
            self.family.up(p, cfg, self.global_cfg, seed=self.embed_seed)
            for p, cfg in zip(client_params, self.client_cfgs)])

    def client_view(self, stacked, k: int):
        return jax.tree.map(lambda x: x[k], stacked)

    # ------------------------------------------------------------ training
    def train_round(self, stacked, stacked_batches: Sequence, *, masks=None):
        """Run one local-training round: fresh optimizer state (matching
        the per-client loop, which re-inits SGD momentum every round), one
        step per stacked batch. ``masks`` defaults to the full-cohort
        strict masks; pass a gathered subset for partial rounds."""
        masks = self.masks if masks is None else masks
        step = self._step_for(jax.tree.leaves(masks)[0].shape[0])
        opt_state = self._opt.init(stacked)
        for i, batch in enumerate(stacked_batches):
            stacked, opt_state = step(
                stacked, opt_state, masks, batch,
                jnp.asarray(i, jnp.int32))
        return stacked

    # --------------------------------------------------------- aggregation
    def aggregate_global(self, stacked, global_params=None, selected=None):
        """FedADP Eq. 1-2 over the (sub-)stacked tree, weights
        renormalized over the participating subset.

        ``agg_mode="filler"``: filler_mode="zero" keeps the filler
        constants in the average (the paper's rule — exactly what
        averaging ``up()`` outputs does); "global" (FedADP-U) substitutes
        the server's current values on UNCOVERED coordinates, where
        covered is read from ``core.aggregation.coverage_mask`` under the
        engine's ``coverage`` policy — the same mask the loop reference
        uses, so the two paths agree by construction.

        ``agg_mode="coverage"``: the HeteroFL-style average — each
        coordinate over only the clients that cover it, per-coordinate
        weight renormalization, server values where no participant
        covers.
        """
        w = subset_weights(self.n_samples, selected)
        cov = self._gather(self.cov_masks, selected)
        if self.agg_mode == "coverage":
            assert global_params is not None, \
                'agg_mode="coverage" needs the current global params'
            return fedavg_stacked(stacked, w, masks=cov, renorm=True,
                                  fallback=global_params,
                                  use_kernel=self.use_kernel)
        if self.filler_mode == "global":
            assert global_params is not None
            stacked = jax.tree.map(
                lambda p, m, g: p * m + g[None] * (1 - m),
                stacked, cov, global_params)
        return fedavg_stacked(stacked, w, use_kernel=self.use_kernel)

    def _agg_clustered(self, stacked, selected=None):
        sel = (set(range(len(self.client_cfgs))) if selected is None
               else set(selected))
        new = stacked
        for ids in self.clusters.values():
            ids = [i for i in ids if i in sel]
            if not ids:
                continue
            idx = jnp.asarray(ids)
            sub = jax.tree.map(lambda x: x[idx], stacked)
            agg = fedavg_stacked(sub, subset_weights(self.n_samples, ids),
                                 use_kernel=self.use_kernel)
            new = jax.tree.map(
                lambda n, a: n.at[idx].set(
                    jnp.broadcast_to(a[None], (len(ids),) + a.shape)),
                new, agg)
        return new

    def _flexifed_prefix_paths(self, sel):
        """Chain positions shared by the WHOLE participating subset (same
        layer id) — FlexiFed's common prefix, computed from configs
        alone. The tree paths come from the CLIENTS' chains (identical
        across the subset wherever the ids agree, and preserved by the
        front-aligned embedding); indexing into the union's chain instead
        would mis-map whenever the subset's prefix extends beyond the
        full cohort's."""
        chains = [self.family.chain_paths(self.client_cfgs[i]) for i in sel]
        paths = set()
        for pos in range(min(len(c) for c in chains)):
            if len({c[pos][0] for c in chains}) == 1:
                paths.add(chains[0][pos][1])
            else:
                break
        return paths

    def _prefix_for(self, sel) -> set:
        key = tuple(sel)
        if key not in self._prefix_cache:
            self._prefix_cache[key] = self._flexifed_prefix_paths(sel)
        return self._prefix_cache[key]

    def _agg_flexifed(self, stacked, selected=None):
        """Common prefix averaged over the PARTICIPANTS, remainder within
        (same-architecture cluster ∩ participants) — Clustered-Common.
        Non-participants keep their parameters."""
        sel = (list(range(len(self.client_cfgs))) if selected is None
               else list(selected))
        idx = jnp.asarray(sel)
        glob = fedavg_stacked(jax.tree.map(lambda x: x[idx], stacked),
                              subset_weights(self.n_samples, sel),
                              use_kernel=self.use_kernel)
        clus = self._agg_clustered(stacked, sel)
        prefix = self._prefix_for(sel)

        def pick(path, g, c):
            keys = tuple(str(getattr(p, "key", p)) for p in path)
            if any(keys[:len(pp)] == pp for pp in prefix):
                return c.at[idx].set(
                    jnp.broadcast_to(g[None], (len(sel),) + g.shape))
            return c
        return jax.tree_util.tree_map_with_path(pick, glob, clus)

    # ---------------------------------------------------------- full round
    def run_round(self, state, stacked_batches: Sequence, selected=None):
        """One federated round over the participating subset (default:
        full cohort). ``state`` is the global tree for fedadp and the
        stacked client tree for the per-client-parameter methods; returns
        the same kind. ``stacked_batches`` leaves carry a leading axis of
        ``len(selected)`` (participants only, in ``selected`` order)."""
        sel = self._resolve(selected)
        masks = self._gather(self.masks, sel)
        if self.method == "fedadp":
            # round_start's body with the already-gathered masks (one
            # gather of the union-sized mask tree per round, not two)
            filler = self._gather(self.filler, sel)
            start = jax.tree.map(
                lambda g, m, f: (g[None] * m + f * (1 - m)).astype(g.dtype),
                state, masks, filler)
            trained = self.train_round(start, stacked_batches, masks=masks)
            return self.aggregate_global(trained, state, selected=sel)
        trained = self.train_round(self._gather(state, sel),
                                   stacked_batches, masks=masks)
        new = self._scatter(state, sel, trained)
        if self.method == "clustered":
            return self._agg_clustered(new, sel)
        if self.method == "flexifed":
            return self._agg_flexifed(new, sel)
        if self.method == "standalone":
            return new
        raise ValueError(self.method)
