"""Cohort-parallel unified FL engine (DESIGN.md §2, §5) — packed.

NetChange embeds every heterogeneous client into the cohort's union
architecture, so a whole federated round can run as ONE stacked XLA
program instead of a Python loop over clients:

  * client k's model = the global architecture with a constant *filler*
    on the parameters the client doesn't have (zero blocks for pre-norm
    residual transformers, identity convs for VGG — whatever ``up()``
    would insert) and a 0/1 *trainable mask* on the ones it does; width
    heterogeneity adds the *segment operators* of ``core.segments``:
    ``up()`` is linear (``u = E p + filler``), E duplicates client
    channels into union segments,
  * round state lives on the packed parameter PLANE (``core.plane``):
    the union tree flattens once per round into a contiguous ``(K, P)``
    f32 plane (a static ``PlaneSpec`` records the layout), the four
    parallel coverage trees (mask / filler / aggregation-coverage /
    multiplicity) become four row-aligned planes built once per
    (cohort, seed), participant gathers are row slices (``plane[idx]``)
    instead of per-leaf tree gathers, and round start is the fused
    ``g·m + f·(1−m)`` on planes,
  * local training = ``jax.vmap`` over the unpacked (K, ...) view of the
    plane (pack/unpack are reshape/concat — XLA fuses them away) with
    gradients transformed by ``E Eᵀ`` (per-axis segment sums, 1/c² on
    Net2Net split axes) then mask-projected on the plane — exactly the
    pushforward of the client-shape gradient, so union-space SGD(+
    momentum, from ``repro.optim``) *equals* client-shape SGD. The step
    is jitted ONCE per engine and participating-subset size and DONATES
    the plane buffers (params + optimizer state), so a round trains
    in-place,
  * the client axis (plane rows) is ``shard_map``-ed over a device mesh
    via the ``sharding/rules.py`` machinery (``stacked_client_spec``) —
    local training is embarrassingly parallel over K, so the
    shard-mapped body needs no collectives,
  * aggregation = ONE fused whole-plane kernel pass
    (``kernels/fedavg.plane_agg``: weights, coverage masks,
    multiplicity division, renormalization and fallback substitution in
    a single tiled dispatch — not one per leaf), with the coverage
    semantics single-sourced in ``core.aggregation``.

Partial participation: ``run_round(state, batches, selected=...)`` runs
the round on the ``selected`` ROWS of the plane — weights/masks
renormalize over the subset, per-client rows scatter back,
cluster/prefix aggregation intersects with the participants — so the
engine supports every participation schedule the loop reference does,
bit-compatibly on its exact domain.

Faithfulness (verified in tests/test_unified.py + tests/test_federation.py
against the per-client ``LoopBackend`` reference path; ``UnifiedBackend``
in fl/backends.py is the Federation-facing wrapper around this engine —
DESIGN.md §7):

  * EXACT for depth-heterogeneous cohorts: the filler is a pointwise
    identity in the forward pass, masked gradients keep it constant, and
    aggregating the plane with the filler in place reproduces the
    paper's zero/identity-filler FedAvg literally. Packing changes the
    LAYOUT, not the math: every per-coordinate operation is identical to
    the tree-shaped reference (f32 accumulation; non-f32 leaves are
    re-quantized through their storage dtype each step —
    ``plane.requantize``, a static no-op on all-f32 cohorts).
  * EXACT (to float tolerance) for width-heterogeneous cohorts whose
    embedding is segment-representable (``family.segment_representable``):
    fedadp rounds draw the SAME per-(round, client) To-Wider mappings as
    the loop (``netchange.round_embed_seed``), round start is the
    literal ``up(down(·))`` under the strategy's ``narrow_mode`` (packed
    row-by-row), training keeps the stack in image(E) via the
    segment-projected gradients, and both paths read coverage +
    multiplicity from ``core.aggregation``.

Methods: ``fedadp`` (filler "zero" | "global"), ``clustered``,
``flexifed`` (VGG chain — the common prefix is a COLUMN mask on the
plane, ``PlaneSpec.col_mask``), ``standalone``.

All embedding artifacts (masks, segment matrices, coverage rows) live in
ONE bounded ``netchange.KeyedCache`` shared-sizing with the loop's
``FedADP`` cache; ``cache_stats()`` exposes its counters.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import plane, quant, segments as sg
from repro.core.aggregation import (AGG_MODES, COVERAGE_POLICIES,
                                    client_weights, coverage_and_filler,
                                    default_k_chunk, finish_partials,
                                    global_shapes, loosen, plane_partials,
                                    resolve_agg_layout, stack_trees,
                                    subset_weights)
from repro.core.baselines import _cluster_ids
from repro.core.netchange import (KeyedCache, NARROW_MODES,
                                  round_embed_seed)
from repro.kernels.fedavg import ops as kops
from repro.kernels.fedavg.fedavg import on_tpu
from repro.optim import sgd
from repro.sharding.ctx import CohortCtx

ENGINE_LAYOUTS = ("auto", "plane", "stream")
COMPUTE_DTYPES = ("f32", "bf16")
ATTN_BACKENDS = ("auto", "flash", "blockwise")


def client_embedding(family, client_cfgs: Sequence, global_cfg, *,
                     seed: int = 0):
    """Stacked (strict masks, filler) for embedding a cohort into
    ``global_cfg`` — per-client trees from
    ``core.aggregation.coverage_and_filler``, stacked on a leading K
    axis."""
    masks, fillers = [], []
    for cfg in client_cfgs:
        m, f = coverage_and_filler(family, cfg, global_cfg, seed=seed)
        masks.append(m)
        fillers.append(f)
    return stack_trees(masks), stack_trees(fillers)


# ---- the engine's hot plane algebra as module-level jitted programs:
# eager versions built a handful of full (K_rows, P) temporaries per call
# (BENCH_new.json showed the plane layout losing to the tree path on CPU
# exactly here); module-level jits also share compile caches across
# engines of the same plane shape
@jax.jit
def _fused_round_start(gp: jnp.ndarray, m: jnp.ndarray, f: jnp.ndarray
                       ) -> jnp.ndarray:
    """Depth-only round start on gathered rows: ``up(down(g))`` is
    literally ``g·m + f·(1−m)`` there."""
    return gp[None, :] * m + f * (1.0 - m)


@jax.jit
def _fold_rows(sp: jnp.ndarray, cov_p: jnp.ndarray, gp: jnp.ndarray
               ) -> jnp.ndarray:
    """filler_mode="global" on gathered rows: substitute the server's
    current values on the coordinates a client does not cover."""
    return sp * cov_p + gp[None, :] * (1.0 - cov_p)


@functools.partial(jax.jit, static_argnames=("fmt", "tile"))
def _wire_encode(x, res, mask, *, fmt: str, tile: int):
    """Error-feedback wire encode of a gathered row chunk (ONE jitted
    program per (fmt, tile, masked?) signature — steady-state rounds
    compile nothing): ``core.quant.encode`` on ``(k_chunk, P)`` rows."""
    return quant.encode(x, res, fmt, tile=tile, mask=mask)


@functools.partial(jax.jit,
                   static_argnames=("renorm", "use_kernel", "fold_global"))
def _plane_agg_fused(sp, w, cov_p, mult_p, gp, *, renorm: bool,
                     use_kernel: bool, fold_global: bool):
    """The whole (sub-)plane aggregation as ONE jitted program:
    ``fold_global`` fuses filler_mode="global"'s uncovered-coordinate
    substitution into the same pass (no eager (K, P) temporaries), then
    a single ``plane_agg`` dispatch."""
    if fold_global:
        sp = sp * cov_p + gp[None, :] * (1.0 - cov_p)
        cov_p = mult_p = gp = None
    return kops.plane_agg(sp, w, masks=cov_p, mult=mult_p, fallback=gp,
                          renorm=renorm, use_kernel=use_kernel)


@dataclass
class UnifiedEngine:
    """Runs FL methods in the packed unified space. See module docstring."""
    family: Any
    client_cfgs: Sequence[Any]
    n_samples: Sequence[int]
    lr: float = 0.01
    momentum: float = 0.0
    method: str = "fedadp"
    filler_mode: str = "zero"            # fedadp only: "zero" | "global"
    agg_mode: str = "filler"             # "filler" (Eq. 1) | "coverage"
    coverage: str = "loose"              # what counts as covered when
                                         # aggregating (core.aggregation)
    narrow_mode: str = "paper"           # fedadp distribute: Alg. 3 | fold
    loss_fn: Optional[Callable] = None   # loss(params, batch) under the
                                         # GLOBAL cfg; default: family's
    use_kernel: Optional[bool] = None    # None = auto (Pallas on TPU)
    mesh: Optional[Mesh] = None          # shard the client axis over this
    client_axes: Tuple[str, ...] = ("clients",)
    embed_seed: int = 0                  # base NetChange seed; fedadp
                                         # rounds derive per-(round, k)
                                         # seeds from it (round_embed_seed)
    agg_layout: str = "auto"             # "auto" | "plane" | "stream":
                                         # whole-plane vs O(P·k_chunk)
                                         # streaming fedadp rounds
    k_chunk: Optional[int] = None        # streaming chunk rows (None=auto)
    wire: str = "f32"                    # client->server payload encoding
                                         # (core.quant): "f32" | "bf16" |
                                         # "int8" — non-f32 rides the
                                         # streaming round path
    wire_tile: int = quant.DEFAULT_TILE  # int8 scale tile (lane multiple)
    wire_sparse: bool = False            # ship covered coords only —
                                         # needs agg_mode="coverage"
    compute_dtype: str = "f32"           # "f32" | "bf16": local-training
                                         # compute policy — the (K, P)
                                         # plane stays f32 master weights,
                                         # params are cast once at unpack
                                         # inside the jitted step and
                                         # grads fold back into f32
                                         # optimizer state
    attn_backend: str = "auto"           # "auto" | "flash" | "blockwise":
                                         # attention backend of the local
                                         # training step (ShardCtx knob;
                                         # transformer families only when
                                         # forced off "auto")
    timing: bool = False                 # wall-clock the training phase
                                         # into phase_stats() (adds a
                                         # sync point per train call —
                                         # benches only, off by default)

    def __post_init__(self):
        if self.agg_layout not in ENGINE_LAYOUTS:
            raise ValueError(
                f"agg_layout={self.agg_layout!r}, expected one of "
                f"{ENGINE_LAYOUTS} (the engine has no per-leaf layout — "
                f"'leaf' lives in core.aggregation only)")
        if self.k_chunk is not None and int(self.k_chunk) < 1:
            raise ValueError(f"k_chunk={self.k_chunk!r}, expected a "
                             f"positive int or None")
        if self.agg_mode not in AGG_MODES:
            raise ValueError(f"agg_mode={self.agg_mode!r}, expected one of "
                             f"{AGG_MODES}")
        if self.coverage not in COVERAGE_POLICIES:
            raise ValueError(f"coverage={self.coverage!r}, expected one of "
                             f"{COVERAGE_POLICIES}")
        if self.narrow_mode not in NARROW_MODES:
            raise ValueError(f"narrow_mode={self.narrow_mode!r}, expected "
                             f"one of {NARROW_MODES}")
        if self.wire not in quant.WIRE_FORMATS:
            raise ValueError(f"wire={self.wire!r}, expected one of "
                             f"{quant.WIRE_FORMATS}")
        quant.validate_tile(self.wire_tile)
        if self.wire != "f32":
            if self.method != "fedadp":
                raise ValueError(
                    f"wire={self.wire!r} compresses the fedadp round "
                    f"payloads; method={self.method!r} does not ship "
                    "plane rows through the wire layer")
            if self.agg_layout == "plane":
                raise ValueError(
                    "wire compression aggregates on the streaming path "
                    "(the fused dequantize-accumulate kernel); "
                    "agg_layout='plane' contradicts it — use 'auto' or "
                    "'stream'")
        if self.wire_sparse:
            if self.wire == "f32":
                raise ValueError("wire_sparse needs a compressed wire "
                                 "(wire='bf16' or 'int8')")
            if self.agg_mode != "coverage":
                raise ValueError(
                    "wire_sparse ships only covered coordinates, which "
                    'is exact only under agg_mode="coverage" (uncovered '
                    "coordinates never enter the masked average); "
                    f"agg_mode={self.agg_mode!r} averages them")
        if self.compute_dtype not in COMPUTE_DTYPES:
            raise ValueError(f"compute_dtype={self.compute_dtype!r}, "
                             f"expected one of {COMPUTE_DTYPES}")
        if self.attn_backend not in ATTN_BACKENDS:
            raise ValueError(f"attn_backend={self.attn_backend!r}, "
                             f"expected one of {ATTN_BACKENDS}")
        self._phase_s = {"train": 0.0}
        self.global_cfg = self.family.union(list(self.client_cfgs))
        self.weights = client_weights(self.n_samples)
        self._depth_only = self.family.depth_only(list(self.client_cfgs))
        if not self._depth_only:
            rep = getattr(self.family, "segment_representable", None)
            if rep is None or not rep(list(self.client_cfgs)):
                raise ValueError(
                    "unified engine needs a depth-only or segment-"
                    "representable cohort (family.segment_representable); "
                    "use the loop backend for this cohort")
        self._gshapes = global_shapes(self.family, self.global_cfg)
        # the packed layout: one static spec for every plane this engine
        # touches (round state, masks, filler, coverage, multiplicity)
        self.plane_spec = plane.PlaneSpec.from_tree(self._gshapes)
        # the static segment structure (which leaves/axes are widened) is
        # seed-invariant — only the matrix VALUES change per round seed
        if self._depth_only:
            self._axes_map: Dict = {}
        else:
            specs = [self.family.segment_spec(cfg, self.global_cfg,
                                              seed=self.embed_seed)
                     for cfg in self.client_cfgs]
            self._axes_map = sg.union_axes(specs, self._gshapes)
        self._seg_axes = {"/".join(p): a for p, a in self._axes_map.items()}
        # ONE bounded cache for every embedding artifact — masks, segment
        # matrices, coverage/multiplicity rows, prefix column masks —
        # sharing the sizing rule with the loop's FedADP cache
        self._cache = KeyedCache(n_clients=len(self.client_cfgs))
        # fixed-seed cohort embedding, DEDUPLICATED per unique client
        # config: 100×-scale cohorts repeat a handful of architectures,
        # so the seed-invariant artifacts (strict mask, filler, coverage
        # reading, multiplicity at embed_seed — all functions of the
        # config alone) are built once per UNIQUE config and stored as
        # (U, P) row planes; client k's row is a gather through the uid
        # index. The full (K, P) planes and stacked trees are LAZY
        # caches (cached_property) for tree-facing consumers — the
        # streaming round path only ever gathers chunk rows, keeping
        # round memory O(P·k_chunk) at any K. The strict mask (and with
        # it the strict coverage reading) is seed-invariant even on
        # width cohorts — To-Wider lands a client parameter on EVERY
        # union channel of a widened axis no matter the mapping.
        uid_of: Dict[Any, int] = {}
        for cfg in self.client_cfgs:
            uid_of.setdefault(cfg, len(uid_of))
        self._uniq_cfgs = list(uid_of)
        self._uid = np.asarray([uid_of[c] for c in self.client_cfgs],
                               np.int32)
        self._uid_jnp = jnp.asarray(self._uid)
        utrip = [self._uid_mask(u) for u in range(len(self._uniq_cfgs))]
        self._umask_p = jnp.stack([plane.pack(t[0], self.plane_spec)
                                   for t in utrip])
        self._ufill_p = jnp.stack([plane.pack(t[1], self.plane_spec)
                                   for t in utrip])
        self._ucov_p = jnp.stack([plane.pack(t[2], self.plane_spec)
                                  for t in utrip])
        if self._depth_only:
            self._seg_mats0: Dict = {}
            self._umult_p = None
        else:
            segs = [self._client_seg(k, self.embed_seed)
                    for k in range(len(self.client_cfgs))]
            self._seg_mats0 = sg.stack_matrices([s[0] for s in segs])
            rep = [int(np.argmax(self._uid == u))
                   for u in range(len(self._uniq_cfgs))]
            self._umult_p = jnp.stack([
                plane.pack(self._client_seg(k, self.embed_seed)[1],
                           self.plane_spec) for k in rep])
        self._ctx = CohortCtx(mesh=self.mesh, client_axes=self.client_axes,
                              k_chunk=self.k_chunk)
        self._edge_fns: Dict = {}
        self._agg_stats: Dict = {}
        # per-client error-feedback residual plane (K, P) f32 — lazily
        # allocated on the first compressed round; checkpointed by the
        # Federation so resumed runs bit-match (DESIGN.md §10)
        self._wire_res: Optional[jnp.ndarray] = None
        self._wire_stats: Dict = {}
        self.clusters = _cluster_ids(self.client_cfgs)
        if self.method == "flexifed":
            full = tuple(range(len(self.client_cfgs)))
            self._prefix_paths = self._prefix_for(full)
        self._opt = sgd(self.lr, self.momentum)
        self._steps: Dict[int, Callable] = {}
        self._step_traces: Dict[int, int] = {}

    # ----------------------------------------------------------- embedding
    def cache_stats(self) -> dict:
        """Hit/miss/size/bound of the embedding-artifact cache
        (``netchange.KeyedCache`` — one cache, one bound)."""
        return self._cache.stats()

    def step_stats(self) -> dict:
        """Introspection over the per-subset-size jitted steps — the
        engine's known retrace hazard. ``traces[k]`` counts how many
        times the size-``k`` step's Python body was traced (a trace ==
        a jit cache miss; steady-state rounds must add none), and
        ``cache_sizes`` reports jax's own per-function compile-cache
        entry counts where available. ``analysis.retrace`` and the
        retrace regression test read this."""
        sizes = {}
        for k, f in self._steps.items():
            cs = getattr(f, "_cache_size", None)
            if callable(cs):
                sizes[k] = cs()
        return {"subset_sizes": sorted(self._steps),
                "traces": dict(self._step_traces),
                "cache_sizes": sizes}

    def _uid_mask(self, u: int):
        """(strict mask, filler, cov) of UNIQUE config ``u`` at the fixed
        ``embed_seed`` — the strict mask is seed-invariant always; filler
        and the loose cov reading are seed-invariant on depth-only
        cohorts (the only place the fixed filler/cov are used for
        fedadp). Built once per unique architecture, not per client."""
        def build():
            mask, filler = coverage_and_filler(
                self.family, self._uniq_cfgs[u], self.global_cfg,
                seed=self.embed_seed)
            cov = mask if self.coverage == "strict" else loosen(mask, filler)
            return (mask, filler, cov)
        return self._cache.get(("mask", "uid", u), build)

    def _client_mask(self, k: int):
        """Client k's (strict mask, filler, cov) — a uid-deduplicated
        view of ``_uid_mask``."""
        return self._uid_mask(int(self._uid[k]))

    # ---- lazy full-cohort views (tree-facing consumers only): the
    # streaming round path never touches these, so a K=256 engine holds
    # (U, P) per-uid rows, not four (K, P) planes
    @functools.cached_property
    def masks(self):
        return stack_trees([self._client_mask(k)[0]
                            for k in range(len(self.client_cfgs))])

    @functools.cached_property
    def filler(self):
        return stack_trees([self._client_mask(k)[1]
                            for k in range(len(self.client_cfgs))])

    @functools.cached_property
    def cov_masks(self):
        return stack_trees([self._client_mask(k)[2]
                            for k in range(len(self.client_cfgs))])

    @functools.cached_property
    def masks_p(self):
        return self._umask_p[self._uid_jnp]

    @functools.cached_property
    def filler_p(self):
        return self._ufill_p[self._uid_jnp]

    @functools.cached_property
    def cov_p(self):
        return self._ucov_p[self._uid_jnp]

    @functools.cached_property
    def mult_p(self):
        return (None if self._umult_p is None
                else self._umult_p[self._uid_jnp])

    # ---- chunk-row gathers from the per-uid store: ``(len(ks), P)``
    # rows for a participating chunk, never the full plane
    def _uid_rows(self, store: jnp.ndarray, ks: Sequence[int]
                  ) -> jnp.ndarray:
        return store[self._uid_jnp[jnp.asarray(list(ks))]]

    def _mask_rows(self, ks) -> jnp.ndarray:
        return self._uid_rows(self._umask_p, ks)

    def _filler_rows(self, ks) -> jnp.ndarray:
        return self._uid_rows(self._ufill_p, ks)

    def _cov_rows(self, ks) -> jnp.ndarray:
        return self._uid_rows(self._ucov_p, ks)

    def _mult_rows(self, ks) -> Optional[jnp.ndarray]:
        return (None if self._umult_p is None
                else self._uid_rows(self._umult_p, ks))

    def _client_seg(self, k: int, seed: int):
        """(E Eᵀ matrices, multiplicity tree) for client k at one seed —
        plain numpy from ``segment_spec``, no jnp pushes; bounded LRU."""
        def build():
            spec = self.family.segment_spec(self.client_cfgs[k],
                                            self.global_cfg, seed=seed)
            return (sg.client_matrices(spec, self._axes_map, self._gshapes,
                                       kind="grad"),
                    sg.multiplicity_tree(spec, self._gshapes))
        return self._cache.get(("seg", k, seed), build)

    def _client_cov(self, k: int, seed: int):
        """Aggregation-coverage mask at a round seed. Strict = the
        seed-invariant trainable mask; loose needs the round's filler
        (widened identity-conv taps move with the mapping) — one extra
        pair of ``up`` pushes per (client, seed), cached."""
        if self._depth_only or self.coverage == "strict":
            return self._client_mask(k)[2]

        def build():
            mask, filler = coverage_and_filler(
                self.family, self.client_cfgs[k], self.global_cfg, seed=seed)
            return loosen(mask, filler)
        return self._cache.get(("cov", k, seed), build)

    def _client_cov_row(self, k: int, seed: int) -> jnp.ndarray:
        """Client k's aggregation-coverage mask at a round seed, packed
        to a ``(P,)`` row — cached so a repeated (round, client) costs a
        dict hit, and the per-round plane assembly is one ``stack``."""
        return self._cache.get(
            ("covrow", k, seed),
            lambda: plane.pack(self._client_cov(k, seed), self.plane_spec,
                               what="cov_row"))

    def _client_mult_row(self, k: int, seed: int) -> jnp.ndarray:
        """Client k's multiplicity counts at a round seed as a packed
        ``(P,)`` row (width cohorts only)."""
        return self._cache.get(
            ("multrow", k, seed),
            lambda: plane.pack(self._client_seg(k, seed)[1],
                               self.plane_spec, what="mult_row"))

    def _round_seed(self, round_idx: int, k: int) -> int:
        return round_embed_seed(self.embed_seed, round_idx, k)

    # ------------------------------------------------------------- step fn
    def _step_for(self, k_count: int):
        """The packed SGD step for a cohort (or participating subset) of
        ``k_count`` clients — jitted exactly once per subset size, plane
        buffers donated."""
        if k_count not in self._steps:
            self._steps[k_count] = self._build_step(k_count)
        return self._steps[k_count]

    def _train_cfg(self):
        """Model config of the local training step: the union config,
        with its compute dtype flipped under the bf16 policy (the model
        casts activations to ``cfg.dtype``, so the grad fn must be built
        on the bf16 config — the plane itself never leaves f32)."""
        if self.compute_dtype == "bf16":
            import dataclasses as _dc
            return _dc.replace(self.global_cfg, dtype="bfloat16")
        return self.global_cfg

    def _train_ctx(self):
        """ShardCtx override for a forced attention backend (None when
        "auto" — the family's default ctx already auto-selects)."""
        if self.attn_backend == "auto":
            return None
        from repro.sharding.ctx import ShardCtx
        return ShardCtx(attn_backend=self.attn_backend)

    def _build_step(self, k_count: int):
        if self.loss_fn is not None:
            lf = self.loss_fn

            def grads_one(p, b):
                return jax.grad(lf)(p, b)
        else:
            ctx = self._train_ctx()
            try:
                gf = (self.family.loss_and_grad(self._train_cfg())
                      if ctx is None else
                      self.family.loss_and_grad(self._train_cfg(), ctx=ctx))
            except TypeError as e:
                raise ValueError(
                    f"attn_backend={self.attn_backend!r} needs a family "
                    "whose loss_and_grad accepts a ShardCtx (transformer "
                    "families); this one does not") from e

            def grads_one(p, b):
                return gf(p, b)[1]

        opt = self._opt
        seg_axes = self._seg_axes
        spec = self.plane_spec
        cdt = jnp.bfloat16 if self.compute_dtype == "bf16" else None

        def step_core(sp, opt_state, masks_p, seg_mats, batch, step_idx):
            # the plane unpacks to the stacked tree for the model's grad
            # fn (reshape/concat only — fused away under jit), and the
            # update itself happens back on the plane:
            # width: E Eᵀ per leaf keeps the update in image(E) and equal
            # to the client-shape SGD step; depth: the 0/1 mask row keeps
            # the filler constant. The two commute (masks are constant
            # along segment axes).
            params = plane.unpack_stacked(sp, spec)
            if cdt is not None:
                # bf16 compute policy: cast ONCE at unpack — the f32 plane
                # stays the master copy, the whole fwd/bwd runs in bf16,
                # and the grads rejoin the f32 optimizer state below
                params = jax.tree_util.tree_map(
                    lambda x: x.astype(cdt), params)
            grads = jax.vmap(grads_one)(params, batch)
            if cdt is not None:
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.float32), grads)
            grads = sg.project_stacked(grads, seg_axes, seg_mats)
            gp = plane.pack_stacked(grads, spec) * masks_p
            new_sp, new_state = opt.update(gp, opt_state, sp, step_idx)
            # reproduce the tree path's per-step storage rounding for
            # non-f32 leaves (static no-op on all-f32 cohorts)
            return plane.requantize(new_sp, spec), new_state

        fn = step_core
        if self.mesh is not None:
            pspec = self._ctx.row_spec(k_count)
            if pspec != P():
                # local training is independent per client: every operand
                # carries the K axis (plane rows, mask rows, stacked
                # matrices, batch), the body needs no collectives.
                fn = shard_map(step_core, mesh=self.mesh,
                               in_specs=(pspec, pspec, pspec, pspec, pspec,
                                         P()),
                               out_specs=(pspec, pspec), check_rep=False)
        inner = fn

        def fn(sp, opt_state, masks_p, seg_mats, batch, step_idx):
            # this Python body runs only when jit (re)traces — i.e. on a
            # compile-cache miss — so the counter measures retraces
            self._step_traces[k_count] = \
                self._step_traces.get(k_count, 0) + 1
            return inner(sp, opt_state, masks_p, seg_mats, batch, step_idx)

        # the round state is consumed step-over-step: donating the plane
        # and the optimizer-state plane lets XLA update them in place
        return jax.jit(fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------- subsets
    def _resolve(self, selected) -> Optional[list]:
        """None = full participation; otherwise the participating subset."""
        if selected is None:
            return None
        sel = list(selected)
        return None if sel == list(range(len(self.client_cfgs))) else sel

    @staticmethod
    def _rows(plane_arr, selected):
        """Participant gather on a plane = ONE row slice."""
        if plane_arr is None or selected is None:
            return plane_arr
        return plane_arr[jnp.asarray(list(selected))]

    @staticmethod
    def _gather(tree, selected):
        if selected is None:
            return tree
        idx = jnp.asarray(selected)
        return jax.tree.map(lambda x: x[idx], tree)

    @staticmethod
    def _scatter(tree, selected, sub):
        if selected is None:
            return sub
        idx = jnp.asarray(selected)
        return jax.tree.map(lambda t, s: t.at[idx].set(s), tree, sub)

    # ----------------------------------------------------------- embedding
    def init_global(self, key):
        return self.family.init(key, self.global_cfg)

    def _round_start_packed(self, gp: jnp.ndarray, selected=None
                            ) -> jnp.ndarray:
        """Depth-only round start, fused on planes: ``up(down(g))`` is
        literally ``g·m + f·(1−m)`` there — one jitted broadcast over
        the uid-gathered mask/filler rows instead of a per-leaf
        tree-map."""
        ks = (range(len(self.client_cfgs)) if selected is None
              else list(selected))
        return _fused_round_start(gp, self._mask_rows(ks),
                                  self._filler_rows(ks))

    def round_start(self, global_params, selected=None, round_idx: int = 0):
        """Stacked per-client views of a global model: the unified-space
        equivalent of FedADP's distribute (To-Shallower/To-Narrower),
        restricted to the participating subset when given. Depth-only
        cohorts use the fused packed mask/filler arithmetic
        (``_round_start_packed``); width cohorts run the literal
        per-client ``up(down(g))`` at the round's seeds under
        ``narrow_mode`` — the same NetChange work the loop's distribute
        + collect would do, with training still stacked."""
        if self._depth_only:
            gp = plane.pack(global_params, self.plane_spec)
            return plane.unpack_stacked(
                self._round_start_packed(gp, selected), self.plane_spec)
        return plane.unpack_stacked(
            self._round_start_width(global_params, selected, round_idx),
            self.plane_spec)

    def _round_start_width(self, global_params, selected, round_idx: int
                           ) -> jnp.ndarray:
        ks = (list(range(len(self.client_cfgs))) if selected is None
              else list(selected))
        views = []
        for k in ks:
            s = self._round_seed(round_idx, k)
            down = self.family.down(global_params, self.global_cfg,
                                    self.client_cfgs[k], seed=s,
                                    mode=self.narrow_mode)
            views.append(self.family.up(down, self.client_cfgs[k],
                                        self.global_cfg, seed=s))
        return plane.pack_trees(views, self.plane_spec)

    def embed(self, client_params: Sequence):
        """Stack per-client (client-space) trees into the unified space
        at the FIXED ``embed_seed`` — the per-client-state layout, where
        same-architecture clients must share one mapping so cluster and
        prefix averages commute with the embedding."""
        return stack_trees([
            self.family.up(p, cfg, self.global_cfg, seed=self.embed_seed)
            for p, cfg in zip(client_params, self.client_cfgs)])

    def client_view(self, stacked, k: int):
        return jax.tree.map(lambda x: x[k], stacked)

    # ------------------------------------------------------------ training
    def _train_packed(self, sp: jnp.ndarray, stacked_batches: Sequence,
                      masks_p: jnp.ndarray, seg_mats) -> jnp.ndarray:
        """One local-training round on the packed plane: fresh optimizer
        state (matching the per-client loop, which re-inits SGD momentum
        every round), one donated jitted step per stacked batch."""
        t0 = time.perf_counter() if self.timing else 0.0
        step = self._step_for(int(sp.shape[0]))
        opt_state = self._opt.init(sp)
        for i, batch in enumerate(stacked_batches):
            sp, opt_state = step(sp, opt_state, masks_p, seg_mats, batch,
                                 jnp.asarray(i, jnp.int32))
        if self.timing:
            jax.block_until_ready(sp)
            self._phase_s["train"] += time.perf_counter() - t0
        return sp

    def phase_stats(self, reset: bool = False):
        """Cumulative wall-clock seconds per round phase (``timing=True``
        only; ``train`` = the donated jitted local-training steps, every
        layout and chunk included). The bench derives the aggregation
        share as round minus train."""
        out = dict(self._phase_s)
        if reset:
            for k in self._phase_s:
                self._phase_s[k] = 0.0
        return out

    def _train_packed_chunked(self, sp: jnp.ndarray,
                              stacked_batches: Sequence,
                              masks_p: jnp.ndarray, seg_mats,
                              k_chunk: int) -> jnp.ndarray:
        """``_train_packed`` in ``k_chunk``-row chunks: the per-client
        -state methods must keep the full ``(K, P)`` state anyway, but
        chunking bounds the TRAINING working set (grads + donated
        optimizer plane) to O(P·k_chunk), and equal chunk sizes reuse
        one per-size jitted step."""
        parts = []
        for lo, hi in plane.chunk_bounds(int(sp.shape[0]), k_chunk):
            parts.append(self._train_packed(
                sp[lo:hi],
                [jax.tree.map(lambda a: a[lo:hi], b)
                 for b in stacked_batches],
                masks_p[lo:hi],
                jax.tree.map(lambda a: a[lo:hi], seg_mats)))
        return jnp.concatenate(parts, axis=0)

    def train_round(self, stacked, stacked_batches: Sequence, *, masks=None,
                    seg_mats=None):
        """Tree-facing wrapper over ``_train_packed``: packs the stacked
        tree (and mask tree, when given) once, trains on the plane,
        unpacks once. ``masks``/``seg_mats`` default to the fixed-seed
        full-cohort embedding; pass gathered/per-round values for
        partial or fedadp width rounds."""
        masks_p = (self.masks_p if masks is None
                   else plane.pack_stacked(masks, self.plane_spec,
                                           what="train_round/masks"))
        seg_mats = self._seg_mats0 if seg_mats is None else seg_mats
        sp = plane.pack_stacked(stacked, self.plane_spec,
                                what="train_round")
        return plane.unpack_stacked(
            self._train_packed(sp, stacked_batches, masks_p, seg_mats),
            self.plane_spec)

    # --------------------------------------------------------- aggregation
    def _use_kernel(self) -> bool:
        return on_tpu() if self.use_kernel is None else bool(self.use_kernel)

    def agg_stats(self) -> dict:
        """Accounting of the LAST aggregation pass — layout, row count,
        and ``peak_bytes`` (the resident aggregation working set: the
        whole ``(K, P)`` sub-plane for layout "plane"; three ``(P,)``
        buffers + one ``(k_chunk, P)`` chunk for "stream" —
        ``PlaneAccumulator.stats``). The bench's peak-memory column and
        the O(P·k_chunk) envelope test read this."""
        return dict(self._agg_stats)

    def wire_stats(self) -> dict:
        """Byte accounting of the LAST compressed round (empty when
        ``wire="f32"``): payload ``bytes_per_round`` (values + int8
        scale grids, covered coordinates only under ``wire_sparse``),
        the dense-f32 baseline, and the reduction factor."""
        return dict(self._wire_stats)

    def wire_residuals(self) -> Optional[jnp.ndarray]:
        """The per-client error-feedback residual plane ``(K, P)`` f32 —
        ``None`` until a compressed round has run (or when
        ``wire="f32"``). What the Federation checkpoints."""
        return self._wire_res

    def load_wire_residuals(self, arr):
        """Restore a checkpointed residual plane (resume path)."""
        arr = jnp.asarray(arr, jnp.float32)
        want = (len(self.client_cfgs), self.plane_spec.size)
        if tuple(arr.shape) != want:
            raise ValueError(f"wire residual plane has shape "
                             f"{tuple(arr.shape)}, engine expects {want}")
        self._wire_res = arr

    def _wire_cov_count(self, k: int, seed) -> int:
        """Covered-coordinate count of client k's aggregation-coverage
        row (the sparse wire's payload length) — cached per (uid, seed)
        so steady-state rounds do no device syncs."""
        key = (("covcount", "uid", int(self._uid[k]))
               if (self._depth_only or self.coverage == "strict")
               else ("covcount", k, seed))
        return self._cache.get(
            key, lambda: int(np.asarray(
                jnp.sum(self._client_cov_row(k, 0 if seed is None
                                             else seed)))))

    def _aggregate_packed(self, sp: jnp.ndarray, w, gp=None, cov_p=None,
                          mult_p=None) -> jnp.ndarray:
        """FedADP Eq. 1-2 over the (sub-)plane in ONE fused jitted pass
        (``_plane_agg_fused`` → ``kernels/fedavg.plane_agg``) — weights
        already renormalized over the participating subset by the
        caller."""
        w = jnp.asarray(w, jnp.float32)
        self._agg_stats = {
            "layout": "plane", "k_chunk": None,
            "rows": int(sp.shape[0]), "n": int(sp.shape[1]),
            "peak_bytes": 4 * int(sp.shape[0]) * int(sp.shape[1])}
        uk = self._use_kernel()
        if self.agg_mode == "coverage":
            assert gp is not None, \
                'agg_mode="coverage" needs the current global params'
            return _plane_agg_fused(sp, w, cov_p, mult_p, gp, renorm=True,
                                    use_kernel=uk, fold_global=False)
        if self.filler_mode == "global":
            assert gp is not None
            return _plane_agg_fused(sp, w, cov_p, None, gp, renorm=True,
                                    use_kernel=uk, fold_global=True)
        return _plane_agg_fused(sp, w, None, None, None, renorm=True,
                                use_kernel=uk, fold_global=False)

    def _edge_fn(self, k_count: int, pspec, has_mask: bool, has_mult: bool,
                 fold: bool):
        """Build (once per signature) the shard-mapped edge reduce: each
        device runs the pure-jnp ``aggregation.plane_partials`` on its
        LOCAL rows, a ``psum`` over the client axes is the global reduce
        — exact by associativity, no gather of the full plane on any
        device."""
        axes = (self.client_axes if len(self.client_axes) > 1
                else self.client_axes[0])

        def psum3(trip):
            return tuple(jax.lax.psum(t, axes) for t in trip)

        if fold:
            def body(sp, w, cov_p, gp):
                folded = sp * cov_p + gp[None, :] * (1.0 - cov_p)
                return psum3(plane_partials(folded, w))
            in_specs = (pspec, pspec, pspec, P())
        elif has_mult:
            def body(sp, w, cov_p, mult_p):
                return psum3(plane_partials(sp, w, cov_p, mult_p))
            in_specs = (pspec, pspec, pspec, pspec)
        elif has_mask:
            def body(sp, w, cov_p):
                return psum3(plane_partials(sp, w, cov_p))
            in_specs = (pspec, pspec, pspec)
        else:
            def body(sp, w):
                return psum3(plane_partials(sp, w))
            in_specs = (pspec, pspec)
        return jax.jit(shard_map(body, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=(P(), P(), P()),
                                 check_rep=False))

    def _edge_reduce_packed(self, sp: jnp.ndarray, w, gp=None, cov_p=None,
                            mult_p=None) -> Optional[jnp.ndarray]:
        """Two-level hierarchical aggregation over the cohort mesh
        (DESIGN.md §9): sub-cohort "edge" reducers (one per mesh slot of
        the client axes) pre-reduce their rows to partial
        (num, den, cov) triples, the psum combines them, and ONE
        replicated finish pass closes. Weights are the GLOBAL subset
        weights — per-edge renormalization would be wrong and never
        happens. Returns ``None`` when the rows don't shard over the
        mesh (caller falls back to the flat fused pass)."""
        if self.mesh is None:
            return None
        k_count = int(sp.shape[0])
        pspec = self._ctx.row_spec(k_count)
        if pspec == P():
            return None
        coverage = self.agg_mode == "coverage"
        fold = (not coverage) and self.filler_mode == "global"
        has_mask = coverage and cov_p is not None
        has_mult = coverage and mult_p is not None
        key = (k_count, has_mask, has_mult, fold)
        if key not in self._edge_fns:
            self._edge_fns[key] = self._edge_fn(k_count, pspec, has_mask,
                                                has_mult, fold)
        fn = self._edge_fns[key]
        w = jnp.asarray(w, jnp.float32)
        if fold:
            trip = fn(sp, w, cov_p, gp)
        elif has_mult:
            trip = fn(sp, w, cov_p, mult_p)
        elif has_mask:
            trip = fn(sp, w, cov_p)
        else:
            trip = fn(sp, w)
        self._agg_stats = {
            "layout": "edge", "k_chunk": None, "rows": k_count,
            "n": int(sp.shape[1]), "edges": self._ctx.edge_extent,
            "peak_bytes": 4 * int(sp.shape[1]) * (
                3 + -(-k_count // max(self._ctx.edge_extent, 1)))}
        return finish_partials(*trip, renorm=coverage,
                               fallback=gp if coverage else None)

    def aggregate_global(self, stacked, global_params=None, selected=None,
                         *, cov=None, mult=None):
        """FedADP Eq. 1-2 over the (sub-)stacked tree, weights
        renormalized over the participating subset.

        ``agg_mode="filler"``: filler_mode="zero" keeps the filler
        constants in the average (the paper's rule — exactly what
        averaging ``up()`` outputs does); "global" (FedADP-U) substitutes
        the server's current values on UNCOVERED coordinates, where
        covered is read from ``core.aggregation.coverage_mask`` under the
        engine's ``coverage`` policy — the same mask the loop reference
        uses, so the two paths agree by construction.

        ``agg_mode="coverage"``: the HeteroFL-style average — each
        coordinate over only the clients that cover it, per-coordinate
        weight renormalization (multiplicity-aware on width cohorts:
        W_k/m_k per duplicated coordinate), server values where no
        participant covers.

        Tree-facing wrapper: packs once, runs the ONE fused plane pass
        (``_aggregate_packed``), unpacks once. ``cov``/``mult`` override
        the fixed-seed embedding's masks for per-round-seeded fedadp
        width rounds.
        """
        spec = self.plane_spec
        w = subset_weights(self.n_samples, selected)
        sp = plane.pack_stacked(stacked, spec, what="aggregate_global")
        need_global = (self.agg_mode == "coverage"
                       or self.filler_mode == "global")
        gp = (plane.pack(global_params, spec, what="aggregate_global/"
                         "global") if global_params is not None
              and need_global else None)
        cov_p = mult_p = None
        if need_global:
            if self.agg_mode == "coverage":
                assert global_params is not None, \
                    'agg_mode="coverage" needs the current global params'
            cov_p = (plane.pack_stacked(cov, spec, what="aggregate_global/"
                                        "cov") if cov is not None
                     else self._rows(self.cov_p, selected))
            if self.agg_mode == "coverage":
                mult_p = (plane.pack_stacked(mult, spec,
                                             what="aggregate_global/mult")
                          if mult is not None
                          else self._rows(self.mult_p, selected))
        return plane.unpack(
            self._aggregate_packed(sp, w, gp, cov_p, mult_p), spec)

    def _agg_clustered_p(self, sp: jnp.ndarray, selected=None
                         ) -> jnp.ndarray:
        """Per-cluster FedAvg on the plane: each (cluster ∩ participants)
        aggregates with one row-sliced ``plane_agg`` pass and broadcasts
        back onto its rows; non-participants keep their rows."""
        sel = (set(range(len(self.client_cfgs))) if selected is None
               else set(selected))
        new = sp
        for ids in self.clusters.values():
            ids = [i for i in ids if i in sel]
            if not ids:
                continue
            idx = jnp.asarray(ids)
            agg = kops.plane_agg(sp[idx],
                                 jnp.asarray(subset_weights(self.n_samples,
                                                            ids),
                                             jnp.float32),
                                 use_kernel=self.use_kernel)
            new = new.at[idx].set(
                jnp.broadcast_to(agg[None, :], (len(ids), sp.shape[1])))
        return new

    def _flexifed_prefix_paths(self, sel):
        """Chain positions shared by the WHOLE participating subset (same
        layer id) — FlexiFed's common prefix, computed from configs
        alone. The tree paths come from the CLIENTS' chains (identical
        across the subset wherever the ids agree, and preserved by the
        front-aligned embedding); indexing into the union's chain instead
        would mis-map whenever the subset's prefix extends beyond the
        full cohort's. Layer ids carry widths, so the prefix stops at
        the first width divergence; on the prefix every participant's
        embedding is the same operator (same tag/widths/fixed seed), so
        averaging embedded prefixes equals embedding the averaged
        prefix."""
        chains = [self.family.chain_paths(self.client_cfgs[i]) for i in sel]
        paths = set()
        for pos in range(min(len(c) for c in chains)):
            if len({c[pos][0] for c in chains}) == 1:
                paths.add(chains[0][pos][1])
            else:
                break
        return paths

    def _prefix_for(self, sel) -> set:
        key = tuple(sel)
        return self._cache.get(("prefix", key),
                               lambda: self._flexifed_prefix_paths(key))

    def _prefix_cols(self, sel) -> jnp.ndarray:
        """The FlexiFed common prefix as a 0/1 COLUMN mask on the plane
        (``PlaneSpec.col_mask``) — prefix substitution becomes one fused
        arithmetic expression instead of a per-leaf path walk."""
        key = tuple(sel)

        def build():
            prefix = self._prefix_for(key)
            return jnp.asarray(self.plane_spec.col_mask(
                lambda path: any(path[:len(pp)] == pp for pp in prefix)))
        return self._cache.get(("prefixcols", key), build)

    def _agg_flexifed_p(self, sp: jnp.ndarray, selected=None
                        ) -> jnp.ndarray:
        """Common prefix averaged over the PARTICIPANTS, remainder within
        (same-architecture cluster ∩ participants) — Clustered-Common.
        Non-participants keep their rows."""
        sel = (list(range(len(self.client_cfgs))) if selected is None
               else list(selected))
        idx = jnp.asarray(sel)
        glob = kops.plane_agg(sp[idx],
                              jnp.asarray(subset_weights(self.n_samples,
                                                         sel), jnp.float32),
                              use_kernel=self.use_kernel)
        clus = self._agg_clustered_p(sp, sel)
        cm = self._prefix_cols(sel)
        sub = clus[idx]
        return clus.at[idx].set(sub * (1.0 - cm) + glob[None, :] * cm)

    # ---------------------------------------------------------- full round
    def run_round(self, state, stacked_batches: Sequence, selected=None,
                  round_idx: int = 0):
        """One federated round over the participating subset (default:
        full cohort). ``state`` is the global tree for fedadp and the
        stacked client tree for the per-client-parameter methods; returns
        the same kind. ``stacked_batches`` leaves carry a leading axis of
        ``len(selected)`` (participants only, in ``selected`` order).
        ``round_idx`` seeds fedadp's per-round To-Wider mappings (the
        loop's ``FedADP._seed`` numbers — identical on both paths).

        The round state is packed ONCE on entry and unpacked ONCE on
        exit; everything between — round start, training steps (donated
        buffers), participant gathers (row slices), aggregation (one
        fused kernel pass) — happens on the plane."""
        sel = self._resolve(selected)
        spec = self.plane_spec
        if self.method == "fedadp":
            ks = (list(range(len(self.client_cfgs))) if sel is None
                  else list(sel))
            layout = resolve_agg_layout(self.agg_layout, k=len(ks),
                                        p=spec.size, k_chunk=self.k_chunk)
            # a compressed wire ALWAYS streams: the fused dequantize-
            # accumulate kernel is the only consumer of int8 chunks, and
            # bf16 chunks ride the same casting accumulate
            if layout == "stream" or self.wire != "f32":
                return self._run_fedadp_stream(state, stacked_batches, sel,
                                               round_idx)
            w = subset_weights(self.n_samples, sel)
            gp = plane.pack(state, spec, what="run_round/state")
            need_cov = (self.agg_mode == "coverage"
                        or self.filler_mode == "global")
            if self._depth_only:
                start = self._round_start_packed(gp, sel)
                trained = self._train_packed(
                    start, stacked_batches, self._mask_rows(ks), {})
                cov_p = self._cov_rows(ks) if need_cov else None
                out = self._edge_reduce_packed(
                    trained, w, gp if need_cov else None, cov_p, None)
                if out is None:
                    out = self._aggregate_packed(
                        trained, w, gp if need_cov else None, cov_p, None)
                return plane.unpack(out, spec)
            seeds = [self._round_seed(round_idx, k) for k in ks]
            segs = [self._client_seg(k, s) for k, s in zip(ks, seeds)]
            seg_mats = sg.stack_matrices([s[0] for s in segs])
            start = self._round_start_width(state, sel, round_idx)
            trained = self._train_packed(
                start, stacked_batches,
                self._mask_rows(ks),               # seed-invariant rows
                seg_mats)
            cov_p = (jnp.stack([self._client_cov_row(k, s)
                                for k, s in zip(ks, seeds)])
                     if need_cov else None)
            mult_p = (jnp.stack([self._client_mult_row(k, s)
                                 for k, s in zip(ks, seeds)])
                      if self.agg_mode == "coverage" else None)
            out = self._edge_reduce_packed(
                trained, w, gp if need_cov else None, cov_p, mult_p)
            if out is None:
                out = self._aggregate_packed(
                    trained, w, gp if need_cov else None, cov_p, mult_p)
            return plane.unpack(out, spec)
        # per-client-state methods: the stacked tree packs to (K, P),
        # participants are row slices, and the state scatters back as rows
        sp = plane.pack_stacked(state, spec, what="run_round/state")
        ks = (list(range(len(self.client_cfgs))) if sel is None
              else list(sel))
        masks_p = self._mask_rows(ks)
        seg_mats = self._gather(self._seg_mats0, sel)
        if self.k_chunk is not None:
            trained = self._train_packed_chunked(
                self._rows(sp, sel), stacked_batches, masks_p, seg_mats,
                default_k_chunk(len(ks), self.k_chunk))
        else:
            trained = self._train_packed(self._rows(sp, sel),
                                         stacked_batches, masks_p, seg_mats)
        if sel is None:
            new = trained
        else:
            new = sp.at[jnp.asarray(sel)].set(trained)
        if self.method == "clustered":
            new = self._agg_clustered_p(new, sel)
        elif self.method == "flexifed":
            new = self._agg_flexifed_p(new, sel)
        elif self.method != "standalone":
            raise ValueError(self.method)
        return plane.unpack_stacked(new, spec)

    def _run_fedadp_stream(self, state, stacked_batches: Sequence, sel,
                           round_idx: int):
        """The streaming fedadp round (DESIGN.md §9): the participating
        cohort is consumed in ``k_chunk``-row chunks — round start, local
        training and the aggregation UPDATE all happen per chunk, so no
        more than one ``(k_chunk, P)`` slab of round state is ever
        resident (plus the accumulator's three ``(P,)`` buffers);
        ``finish`` closes with the one divide/fallback pass. Identical
        math to the whole-plane round for every agg/filler mode (the
        masked weighted sum splits associatively; weights stay the GLOBAL
        subset weights), verified to 1e-6 in tests/test_streaming.py.
        Chunks of equal size reuse one per-size jitted training step and
        one accumulate program — steady-state rounds compile nothing
        (tests/test_retrace.py)."""
        spec = self.plane_spec
        ks = (list(range(len(self.client_cfgs))) if sel is None
              else list(sel))
        w = subset_weights(self.n_samples, sel)
        gp = plane.pack(state, spec, what="run_round/state")
        kc = default_k_chunk(len(ks), self.k_chunk)
        coverage = self.agg_mode == "coverage"
        fold = (not coverage) and self.filler_mode == "global"
        wire = self.wire
        if wire != "f32" and (self._wire_res is None or round_idx == 0):
            # round 0 = a FRESH run: residuals start at zero. The engine
            # (and its residual plane) outlives a Federation.run, so a
            # second run on the same backend must not inherit the first
            # one's error feedback; a resume (round_idx > 0) keeps what
            # load_wire_residuals restored.
            self._wire_res = jnp.zeros((len(self.client_cfgs), spec.size),
                                       jnp.float32)
        acc = kops.PlaneAccumulator(
            spec.size, use_kernel=self._use_kernel(), k_hint=kc,
            q_tile=self.wire_tile if wire == "int8" else None)
        payload_bytes = 0
        for lo, hi in plane.chunk_bounds(len(ks), kc):
            cks = ks[lo:hi]
            m_rows = self._mask_rows(cks)
            if self._depth_only:
                seeds = None
                seg_mats: Dict = {}
                start = _fused_round_start(gp, m_rows,
                                           self._filler_rows(cks))
            else:
                seeds = [self._round_seed(round_idx, k) for k in cks]
                segs = [self._client_seg(k, s)
                        for k, s in zip(cks, seeds)]
                seg_mats = sg.stack_matrices([s[0] for s in segs])
                start = self._round_start_width(state, cks, round_idx)
            trained = self._train_packed(
                start,
                [jax.tree.map(lambda a: a[lo:hi], b)
                 for b in stacked_batches],
                m_rows, seg_mats)
            wk = jnp.asarray(w[lo:hi], jnp.float32)
            cov_rows = mult_rows = None
            if coverage or fold:
                cov_rows = (self._cov_rows(cks) if self._depth_only
                            else jnp.stack([self._client_cov_row(k, s)
                                            for k, s in zip(cks, seeds)]))
            if coverage:
                mult_rows = (None if self._depth_only
                             else jnp.stack([self._client_mult_row(k, s)
                                             for k, s in zip(cks, seeds)]))
            if wire != "f32":
                # error-feedback encode the chunk for the wire: the
                # residual rows gather/scatter by client index, the
                # payload aggregates through the fused dequantize-
                # accumulate kernel (int8) or the casting accumulate
                # (bf16) — the f32 cohort never materializes
                idx = jnp.asarray(cks)
                vals, scales, new_res = _wire_encode(
                    trained, self._wire_res[idx],
                    cov_rows if self.wire_sparse else None,
                    fmt=wire, tile=self.wire_tile)
                self._wire_res = self._wire_res.at[idx].set(new_res)
                counts = ([self._wire_cov_count(
                               k, None if seeds is None else s)
                           for k, s in zip(cks, seeds or cks)]
                          if self.wire_sparse else None)
                for j, k in enumerate(cks):
                    payload_bytes += quant.payload_nbytes(
                        wire, spec.size, tile=self.wire_tile,
                        covered=None if counts is None else counts[j])
                if wire == "int8":
                    if coverage:
                        acc.update_q(vals, scales, wk, masks=cov_rows,
                                     mult=mult_rows)
                    elif fold:
                        acc.update_q(vals, scales, wk, masks=cov_rows,
                                     base=gp)
                    else:
                        acc.update_q(vals, scales, wk)
                elif coverage:
                    acc.update(vals, wk, masks=cov_rows, mult=mult_rows)
                elif fold:
                    acc.update(_fold_rows(vals, cov_rows, gp), wk)
                else:
                    acc.update(vals, wk)
            elif coverage:
                acc.update(trained, wk, masks=cov_rows, mult=mult_rows)
            elif fold:
                acc.update(_fold_rows(trained, cov_rows, gp), wk)
            else:
                acc.update(trained, wk)
        out = acc.finish(renorm=coverage,
                         fallback=gp if coverage else None)
        self._agg_stats = {"layout": "stream", "k_chunk": kc,
                           **acc.stats()}
        if wire != "f32":
            f32_bytes = len(ks) * spec.size * 4
            self._wire_stats = {
                "wire": wire, "tile": self.wire_tile,
                "sparse": self.wire_sparse, "rows": len(ks),
                "bytes_per_round": int(payload_bytes),
                "f32_bytes": int(f32_bytes),
                "reduction": f32_bytes / max(payload_bytes, 1)}
        return plane.unpack(out, spec)
