"""Back-compat facade: ``Simulator``/``FLRunConfig`` over ``Federation``.

The orchestration API is the Strategy protocol + Federation orchestrator
(fl/strategy.py, fl/federation.py, fl/backends.py — DESIGN.md §7); this
module keeps the original entry point working unchanged:

    Simulator(family, client_cfgs, samplers, FLRunConfig(...), eval_batch)
        .run() -> {"history", "final_acc", "client_params",
                   "global_params", "wall_s"}

Methods: fedadp | flexifed | clustered | standalone  (Section IV).

Protocol knobs follow Section IV.A.4: K clients, local epochs E over 20%
of the client's data per round, SGD(lr). ``participation`` (beyond-paper)
selects a seeded per-round client subset when < 1 (both engines).

Execution backends (EXPERIMENTS.md §Perf):
  * engine="loop"     — reference path: a Python loop over clients, each
                        trained in its own architecture (LoopBackend).
  * engine="unified"  — cohort-parallel path (UnifiedBackend around
                        fl/engine.py): one stacked vmapped program in the
                        union architecture, shard_map-able over a device
                        mesh. Loop-equivalent on segment-representable
                        cohorts — depth AND width heterogeneity
                        (DESIGN.md §2).
  * engine="auto"     — unified when eligible (backends.unified_eligible),
                        loop otherwise; the fallback reason is logged once
                        (logger "repro.fl",
                        backends.unified_ineligible_reason).

Beyond-paper knobs (ablations in EXPERIMENTS.md):
  * narrow_mode:  "paper" (Alg. 3) | "fold" (function-preserving inverse)
  * filler:       "zero" (paper) | "global" (FedADP-U) — a FedADP
                  strategy option (fl/strategy.py).
  * coverage:     "loose" (reference reading: identity-conv filler taps
                  count as covered) | "strict" (parameter landing sites
                  only) — core.aggregation's single coverage semantics.
  * agg_mode:     "filler" (Eq. 1 verbatim) | "coverage" (HeteroFL-style
                  per-coordinate renormalized average over covering
                  clients; uncovered coordinates keep server values;
                  multiplicity-aware on width-heterogeneous cohorts).
  * embed_seed:   base seed of the NetChange To-Wider mappings (None =
                  follow `seed`); both engines derive identical
                  per-(round, client) mappings from it.
  * agg_layout:   "auto" (default: resolve_agg_layout picks "plane" at
                  small K and "stream" past K=32 / 256 MiB cohorts,
                  logged once per backend) | "plane" | "stream" — the
                  streaming layout aggregates in O(P·k_chunk) memory
                  (DESIGN.md §9).
  * k_chunk:      streaming chunk rows (None = auto, 16); pinning it
                  implies "stream" under agg_layout="auto".

All config values are validated eagerly at ``FLRunConfig`` construction.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import AGG_MODES, COVERAGE_POLICIES, WIRE_FORMATS
from repro.core.quant import validate_tile
from repro.data.federated import ClientSampler
from repro.fl.backends import (LoopBackend, UnifiedBackend,
                               unified_ineligible_reason)
from repro.fl.federation import Federation, Participation
from repro.fl.strategy import FILLERS, METHODS, NARROW_MODES, make_strategy

_ENGINES = ("loop", "unified", "auto")

_log = logging.getLogger("repro.fl")


@dataclass
class FLRunConfig:
    method: str = "fedadp"
    rounds: int = 20
    local_epochs: int = 2
    lr: float = 0.01
    momentum: float = 0.0
    narrow_mode: str = "paper"
    filler: str = "zero"
    coverage: str = "loose"
    agg_mode: str = "filler"
    seed: int = 0
    embed_seed: Optional[int] = None     # NetChange embedding base seed
                                         # (To-Wider mappings); None =
                                         # follow `seed`. Loop and unified
                                         # engines derive IDENTICAL
                                         # per-(round, client) mappings
                                         # from it (round_embed_seed) —
                                         # a user-settable contract
    eval_every: int = 1
    engine: str = "auto"                 # loop | unified | auto
    use_kernel: Optional[bool] = None    # unified path: None = auto (TPU)
    participation: float = 1.0           # client fraction per round
    participation_seed: int = 0          # per-round sampling seed
    agg_layout: str = "auto"             # aggregation layout: auto (pick
                                         # per backend + cohort shape,
                                         # logged once) | plane | stream
    k_chunk: Optional[int] = None        # streaming chunk rows; pinning
                                         # it implies layout "stream"
                                         # under "auto"
    wire: str = "f32"                    # client->server payload encoding
                                         # (core.quant): "f32" (none) |
                                         # "bf16" | "int8"+error feedback;
                                         # non-f32 needs method="fedadp"
                                         # on the unified engine and
                                         # rides the streaming layout
    wire_tile: int = 256                 # int8 scale tile (lane multiple)
    wire_sparse: bool = False            # ship covered coordinates only;
                                         # needs agg_mode="coverage"
    compute_dtype: str = "f32"           # local-training compute: "f32" |
                                         # "bf16" (mixed precision — the
                                         # packed plane and optimizer
                                         # state stay f32 master copies;
                                         # unified engine only)
    attn_backend: str = "auto"           # attention backend of the local
                                         # step: "auto" (flash Pallas on
                                         # TPU, blockwise XLA elsewhere) |
                                         # "flash" | "blockwise" (forced
                                         # values: unified engine only)

    def __post_init__(self):
        # fail at construction, not after `rounds` of work mid-run
        if self.method not in METHODS:
            raise ValueError(
                f"method={self.method!r}, expected one of {METHODS}")
        if self.filler not in FILLERS:
            raise ValueError(
                f"filler={self.filler!r}, expected one of {FILLERS}")
        if self.narrow_mode not in NARROW_MODES:
            raise ValueError(f"narrow_mode={self.narrow_mode!r}, expected "
                             f"one of {NARROW_MODES}")
        if self.coverage not in COVERAGE_POLICIES:
            raise ValueError(f"coverage={self.coverage!r}, expected one of "
                             f"{COVERAGE_POLICIES}")
        if self.agg_mode not in AGG_MODES:
            raise ValueError(f"agg_mode={self.agg_mode!r}, expected one of "
                             f"{AGG_MODES}")
        if self.engine not in _ENGINES:
            raise ValueError(
                f"engine={self.engine!r}, expected one of {_ENGINES}")
        if not (0.0 < self.participation <= 1.0):
            raise ValueError(f"participation={self.participation!r} must "
                             "be in (0, 1]")
        if self.rounds < 0:
            raise ValueError(f"rounds={self.rounds!r} must be >= 0")
        if self.eval_every < 1:
            raise ValueError(f"eval_every={self.eval_every!r} must be >= 1")
        if self.local_epochs < 1:
            raise ValueError(
                f"local_epochs={self.local_epochs!r} must be >= 1")
        if self.embed_seed is not None and (
                isinstance(self.embed_seed, bool)
                or not isinstance(self.embed_seed, int)):
            raise ValueError(f"embed_seed={self.embed_seed!r} must be an "
                             "int (or None to follow `seed`)")
        if self.agg_layout not in ("auto", "plane", "stream"):
            raise ValueError(
                f"agg_layout={self.agg_layout!r}, expected 'auto', "
                "'plane' or 'stream' ('leaf' is the per-leaf reference "
                "layout of core.aggregation, not a run option)")
        if self.k_chunk is not None and (
                isinstance(self.k_chunk, bool)
                or not isinstance(self.k_chunk, int) or self.k_chunk < 1):
            raise ValueError(f"k_chunk={self.k_chunk!r} must be a "
                             "positive int (or None for auto)")
        if self.wire not in WIRE_FORMATS:
            raise ValueError(f"wire={self.wire!r}, expected one of "
                             f"{WIRE_FORMATS}")
        validate_tile(self.wire_tile)
        if self.wire != "f32":
            if self.method != "fedadp":
                raise ValueError(
                    f"wire={self.wire!r} compresses fedadp round "
                    f"payloads; method={self.method!r} has no wire layer")
            if self.engine == "loop":
                raise ValueError(
                    "wire compression needs the unified engine (the "
                    "fused dequantize-accumulate streaming kernel); "
                    "engine='loop' cannot honor it")
            if self.agg_layout == "plane":
                raise ValueError(
                    "wire compression aggregates on the streaming "
                    "layout; agg_layout='plane' contradicts it — use "
                    "'auto' or 'stream'")
        if self.wire_sparse:
            if self.wire == "f32":
                raise ValueError("wire_sparse needs a compressed wire "
                                 "(wire='bf16' or 'int8')")
            if self.agg_mode != "coverage":
                raise ValueError(
                    'wire_sparse is exact only under agg_mode="coverage"'
                    " (only covered coordinates enter the average); "
                    f"agg_mode={self.agg_mode!r} averages uncovered "
                    "coordinates too")
        if self.compute_dtype not in ("f32", "bf16"):
            raise ValueError(f"compute_dtype={self.compute_dtype!r}, "
                             "expected 'f32' or 'bf16'")
        if self.compute_dtype != "f32" and self.engine == "loop":
            raise ValueError(
                "compute_dtype='bf16' is the unified engine's cast-at-"
                "unpack policy (f32 master plane, bf16 step); "
                "engine='loop' cannot honor it")
        if self.attn_backend not in ("auto", "flash", "blockwise"):
            raise ValueError(f"attn_backend={self.attn_backend!r}, "
                             "expected 'auto', 'flash' or 'blockwise'")
        if self.attn_backend != "auto" and self.engine == "loop":
            raise ValueError(
                "a forced attn_backend threads through the unified "
                "engine's training step; engine='loop' cannot honor it")

    @property
    def resolved_embed_seed(self) -> int:
        return self.seed if self.embed_seed is None else self.embed_seed


class Simulator:
    """Thin shim: builds (strategy, backend, Federation) from the config
    once, then delegates ``run()``. Kept so every existing test, example
    and benchmark works unchanged on top of the new API."""

    def __init__(self, family, client_cfgs: Sequence,
                 samplers: List[ClientSampler], run_cfg: FLRunConfig,
                 eval_batch: Dict[str, np.ndarray], mesh=None):
        self.family = family
        self.client_cfgs = list(client_cfgs)
        self.samplers = samplers
        self.cfg = run_cfg
        self.eval_batch = eval_batch
        self.mesh = mesh
        self.n_samples = [s.n_samples for s in samplers]
        # backends (grad fns / the engine's jitted step) are cached across
        # run()s keyed by the cfg fields they depend on; the Federation
        # itself is rebuilt per run so `sim.cfg` mutations (e.g. replacing
        # `rounds` between a warmup and a timed run) take effect.
        self._backends: Dict[tuple, Any] = {}
        self._fallback_logged = False

    # ------------------------------------------------------ engine choice
    def _resolve_engine(self, strategy=None) -> str:
        if self.cfg.engine != "auto":
            return self.cfg.engine
        strategy = strategy if strategy is not None else self._strategy()
        reason = unified_ineligible_reason(
            strategy, self.family, self.client_cfgs, self.samplers)
        if reason is None:
            return "unified"
        if self.cfg.wire != "f32":
            # the loop backend has no wire layer — a silent fallback would
            # run uncompressed while reporting wire=... in the config
            raise ValueError(
                f"wire={self.cfg.wire!r} needs the unified engine, but "
                f"this run is unified-ineligible: {reason}")
        if self.cfg.compute_dtype != "f32":
            raise ValueError(
                f"compute_dtype={self.cfg.compute_dtype!r} needs the "
                f"unified engine, but this run is unified-ineligible: "
                f"{reason}")
        if self.cfg.attn_backend != "auto":
            raise ValueError(
                f"attn_backend={self.cfg.attn_backend!r} needs the "
                f"unified engine, but this run is unified-ineligible: "
                f"{reason}")
        if not self._fallback_logged:
            # once per Simulator: the auto fallback used to be silent and
            # undiagnosable
            _log.info("engine='auto' falls back to the loop backend: %s",
                      reason)
            self._fallback_logged = True
        return "loop"

    def _strategy(self):
        return make_strategy(
            self.cfg.method, self.family, self.client_cfgs, self.n_samples,
            narrow_mode=self.cfg.narrow_mode, filler=self.cfg.filler,
            coverage=self.cfg.coverage, agg_mode=self.cfg.agg_mode,
            base_seed=self.cfg.resolved_embed_seed,
            agg_layout=self.cfg.agg_layout, k_chunk=self.cfg.k_chunk,
            wire=self.cfg.wire, wire_tile=self.cfg.wire_tile,
            wire_sparse=self.cfg.wire_sparse,
            compute_dtype=self.cfg.compute_dtype,
            attn_backend=self.cfg.attn_backend)

    def _backend(self, kind: str):
        cfg = self.cfg
        # key only on what each backend actually depends on, so e.g. a
        # seed sweep on the loop engine keeps its warm grad fns
        bkey = (kind, cfg.local_epochs, cfg.lr, cfg.momentum) + (
            (cfg.use_kernel, cfg.resolved_embed_seed, cfg.agg_layout,
             cfg.k_chunk, cfg.wire, cfg.wire_tile, cfg.wire_sparse,
             cfg.compute_dtype, cfg.attn_backend)
            if kind == "unified" else ())
        if bkey not in self._backends:
            if kind == "unified":
                self._backends[bkey] = UnifiedBackend(
                    self.family, self.client_cfgs, self.samplers,
                    local_epochs=cfg.local_epochs, lr=cfg.lr,
                    momentum=cfg.momentum, use_kernel=cfg.use_kernel,
                    mesh=self.mesh, seed=cfg.resolved_embed_seed,
                    agg_layout=cfg.agg_layout, k_chunk=cfg.k_chunk,
                    wire=cfg.wire, wire_tile=cfg.wire_tile,
                    wire_sparse=cfg.wire_sparse,
                    compute_dtype=cfg.compute_dtype,
                    attn_backend=cfg.attn_backend)
            else:
                self._backends[bkey] = LoopBackend(
                    self.family, self.client_cfgs, self.samplers,
                    local_epochs=cfg.local_epochs, lr=cfg.lr,
                    momentum=cfg.momentum)
        return self._backends[bkey]

    def _build(self) -> Federation:
        cfg = self.cfg
        strategy = self._strategy()
        backend = self._backend(self._resolve_engine(strategy))
        backend.samplers = self.samplers   # like cfg, mutable between runs
        return Federation(
            strategy, backend, rounds=cfg.rounds, eval_batch=self.eval_batch,
            eval_every=cfg.eval_every,
            participation=Participation(cfg.participation,
                                        cfg.participation_seed))

    # -------------------------------------------------------------- runs
    def run(self, key=None) -> Dict[str, Any]:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        return self._build().run(key)
