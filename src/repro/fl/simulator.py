"""Federated-learning simulator: the paper's protocol end to end.

Methods: fedadp | flexifed | clustered | standalone  (Section IV).

Protocol knobs follow Section IV.A.4: K clients, full participation,
local epochs E over 20% of the client's data per round, SGD(lr).

Two execution paths (EXPERIMENTS.md §Perf):
  * engine="loop"     — the reference path: a Python loop over clients,
                        each trained in its own architecture.
  * engine="unified"  — the cohort-parallel path (fl/engine.py): one
                        stacked vmapped program in the union architecture,
                        shard_map-able over a device mesh. Exact for
                        depth-heterogeneous cohorts, approximate under
                        width heterogeneity (DESIGN.md §2).
  * engine="auto"     — unified when the method supports it, the cohort
                        is depth-only and client batch streams align;
                        loop otherwise.

Beyond-paper knobs (ablations in EXPERIMENTS.md):
  * narrow_mode:  "paper" (Alg. 3) | "fold" (function-preserving inverse)
  * filler:       "zero"  (paper: expanded regions a client doesn't have
                  carry zeros / identity filler into the average)
                  | "global" (FedADP-U: the server substitutes its own
                  current values for uncovered regions — uncovered
                  parameters are simply not pulled toward the filler)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedADP, ClusteredFL, FlexiFed, Standalone, vgg_chain
from repro.core.aggregation import client_weights, fedavg
from repro.data.federated import ClientSampler
from repro.fl.engine import UnifiedEngine
from repro.optim import sgd

_UNIFIED_METHODS = ("fedadp", "clustered", "flexifed", "standalone")


@dataclass
class FLRunConfig:
    method: str = "fedadp"
    rounds: int = 20
    local_epochs: int = 2
    lr: float = 0.01
    momentum: float = 0.0
    narrow_mode: str = "paper"
    filler: str = "zero"
    seed: int = 0
    eval_every: int = 1
    engine: str = "auto"                 # loop | unified | auto
    use_kernel: Optional[bool] = None    # unified path: None = auto (TPU)


class Simulator:
    def __init__(self, family, client_cfgs: Sequence, samplers: List[ClientSampler],
                 run_cfg: FLRunConfig, eval_batch: Dict[str, np.ndarray],
                 mesh=None):
        self.family = family
        self.client_cfgs = list(client_cfgs)
        self.samplers = samplers
        self.cfg = run_cfg
        self.eval_batch = eval_batch
        self.mesh = mesh
        self.n_samples = [s.n_samples for s in samplers]
        self._grad_fns: Dict[str, Callable] = {}
        self._engines: Dict[tuple, UnifiedEngine] = {}
        self._opt = sgd(run_cfg.lr, run_cfg.momentum)

    # ------------------------------------------------------------ pieces
    def _grad_fn(self, cfg):
        if cfg.name not in self._grad_fns:
            f = self.family.loss_and_grad(cfg)
            self._grad_fns[cfg.name] = jax.jit(f)
        return self._grad_fns[cfg.name]

    def _local_train(self, k: int, params):
        cfg = self.client_cfgs[k]
        gf = self._grad_fn(cfg)
        opt_state = self._opt.init(params)
        step = 0
        for batch in self.samplers[k].round_batches(self.cfg.local_epochs):
            (_, _), grads = gf(params, batch)
            params, opt_state = self._opt.update(grads, opt_state, params, step)
            step += 1
        return params

    def _evaluate_clients(self, client_params, cfgs=None) -> float:
        cfgs = cfgs if cfgs is not None else self.client_cfgs
        accs = [self.family.evaluate(p, c, self.eval_batch)
                for p, c in zip(client_params, cfgs)]
        return float(np.mean(accs))

    # ------------------------------------------------------ engine choice
    def _resolve_engine(self) -> str:
        eng = self.cfg.engine
        if eng == "auto":
            # equal n_samples + batch_size + round_fraction => every sampler
            # draws the same per-round take, so the stacked batch streams
            # are guaranteed to align (ragged cohorts keep the loop).
            # filler="global" stays on the loop: the two paths define
            # "uncovered" differently on identity-conv filler taps
            # (engine.py aggregate_global docstring).
            ok = (self.cfg.method in _UNIFIED_METHODS
                  and self.cfg.filler == "zero"
                  and self.family.depth_only(self.client_cfgs)
                  and len(set(self.n_samples)) == 1
                  and len({s.batch_size for s in self.samplers}) == 1
                  and len({getattr(s, "round_fraction", None)
                           for s in self.samplers}) == 1)
            return "unified" if ok else "loop"
        if eng not in ("loop", "unified"):
            raise ValueError(f"engine={eng!r}")
        return eng

    # -------------------------------------------------------------- runs
    def run(self, key=None) -> Dict[str, Any]:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        if self._resolve_engine() == "unified":
            return self._run_unified(key)
        return self._run_loop(key)

    def _run_loop(self, key) -> Dict[str, Any]:
        method = self.cfg.method
        hist: List[float] = []
        t0 = time.time()

        if method == "fedadp":
            algo = FedADP(self.family, self.client_cfgs, self.n_samples,
                          narrow_mode=self.cfg.narrow_mode,
                          base_seed=self.cfg.seed)
            gparams = algo.init_global(key)
            for r in range(self.cfg.rounds):
                if self.cfg.filler == "global":
                    gparams = self._round_fedadp_globalfill(algo, gparams, r)
                else:
                    gparams = algo.round(gparams, self._local_train, r)
                if (r + 1) % self.cfg.eval_every == 0:
                    cps = [algo.distribute(gparams, r + 1, k)
                           for k in range(len(self.client_cfgs))]
                    hist.append(self._evaluate_clients(cps))
            final = [algo.distribute(gparams, self.cfg.rounds, k)
                     for k in range(len(self.client_cfgs))]
            return self._result(hist, final, t0, global_params=gparams)

        # per-client-parameter methods
        client_params = [self.family.init(jax.random.fold_in(key, k), c)
                         for k, c in enumerate(self.client_cfgs)]
        if method == "standalone":
            algo = Standalone(self.client_cfgs, self.n_samples)
        elif method == "clustered":
            algo = ClusteredFL(self.client_cfgs, self.n_samples)
        elif method == "flexifed":
            algo = FlexiFed(self.client_cfgs, self.n_samples, vgg_chain)
        else:
            raise ValueError(method)
        for r in range(self.cfg.rounds):
            client_params = algo.round(client_params, self._local_train, r)
            if (r + 1) % self.cfg.eval_every == 0:
                hist.append(self._evaluate_clients(client_params))
        return self._result(hist, client_params, t0)

    # ------------------------------------------------- cohort-parallel run
    def _stacked_round_batches(self) -> List[Dict[str, np.ndarray]]:
        """Draw one round of local batches from every sampler and stack
        them on a leading K axis. Consumes the SAME rng stream per sampler
        as the loop path, so the two paths see identical data."""
        per = [list(s.round_batches(self.cfg.local_epochs))
               for s in self.samplers]
        counts = {len(b) for b in per}
        if len(counts) != 1:
            raise ValueError(
                "unified engine needs aligned client batch streams "
                f"(got per-client step counts {sorted(counts)}); "
                "use engine='loop' for ragged cohorts")
        out = []
        for t in range(counts.pop()):
            shapes = {tuple((k, v.shape) for k, v in sorted(b[t].items()))
                      for b in per}
            if len(shapes) != 1:
                raise ValueError(
                    "unified engine needs identical batch shapes across "
                    "clients; use engine='loop'")
            out.append({k: np.stack([b[t][k] for b in per])
                        for k in per[0][t]})
        return out

    def _run_unified(self, key) -> Dict[str, Any]:
        method = self.cfg.method
        if method not in _UNIFIED_METHODS:
            raise ValueError(f"unified engine does not support {method!r}")
        hist: List[float] = []
        t0 = time.time()
        ekey = (method, self.cfg.filler, self.cfg.lr, self.cfg.momentum,
                self.cfg.use_kernel, self.cfg.seed)
        if ekey not in self._engines:   # keep the jitted step across run()s
            self._engines[ekey] = UnifiedEngine(
                self.family, self.client_cfgs, self.n_samples,
                lr=self.cfg.lr, momentum=self.cfg.momentum, method=method,
                filler_mode=self.cfg.filler, use_kernel=self.cfg.use_kernel,
                mesh=self.mesh, embed_seed=self.cfg.seed)
        eng = self._engines[ekey]
        gcfgs = [eng.global_cfg] * len(self.client_cfgs)

        def eval_stacked(stacked):
            views = [eng.client_view(stacked, k)
                     for k in range(len(self.client_cfgs))]
            return self._evaluate_clients(views, gcfgs)

        if method == "fedadp":
            gparams = eng.init_global(key)
            for r in range(self.cfg.rounds):
                gparams = eng.run_round(gparams, self._stacked_round_batches())
                if (r + 1) % self.cfg.eval_every == 0:
                    hist.append(eval_stacked(eng.round_start(gparams)))
            views = eng.round_start(gparams)
            final = [eng.client_view(views, k)
                     for k in range(len(self.client_cfgs))]
            return self._result(hist, final, t0, global_params=gparams)

        stacked = eng.embed([
            self.family.init(jax.random.fold_in(key, k), c)
            for k, c in enumerate(self.client_cfgs)])
        for r in range(self.cfg.rounds):
            stacked = eng.run_round(stacked, self._stacked_round_batches())
            if (r + 1) % self.cfg.eval_every == 0:
                hist.append(eval_stacked(stacked))
        final = [eng.client_view(stacked, k)
                 for k in range(len(self.client_cfgs))]
        return self._result(hist, final, t0)

    def _round_fedadp_globalfill(self, algo: FedADP, gparams, r: int):
        """FedADP-U: uncovered regions keep the server's values instead of
        the zero/identity filler (beyond-paper; see module docstring)."""
        expanded, masks = [], []
        for k in range(len(self.client_cfgs)):
            ck = algo.distribute(gparams, r, k)
            ck = self._local_train(k, ck)
            up_k = algo.collect(ck, r, k)
            ones = jax.tree.map(jnp.ones_like, ck)
            mask = jax.tree.map(lambda m: (jnp.abs(m) > 0).astype(jnp.float32),
                                algo.collect(ones, r, k))
            filled = jax.tree.map(lambda u, m, g: u * m + g * (1 - m),
                                  up_k, mask, gparams)
            expanded.append(filled)
        w = algo.weights / algo.weights.sum()
        return fedavg(expanded, w)

    def _result(self, hist, client_params, t0, global_params=None):
        return {"history": hist,
                "final_acc": hist[-1] if hist else None,
                "client_params": client_params,
                "global_params": global_params,
                "wall_s": time.time() - t0}
