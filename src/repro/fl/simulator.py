"""Federated-learning simulator: the paper's protocol end to end.

Methods: fedadp | flexifed | clustered | standalone  (Section IV).

Protocol knobs follow Section IV.A.4: K clients, full participation,
local epochs E over 20% of the client's data per round, SGD(lr).

Beyond-paper knobs (ablations in EXPERIMENTS.md):
  * narrow_mode:  "paper" (Alg. 3) | "fold" (function-preserving inverse)
  * filler:       "zero"  (paper: expanded regions a client doesn't have
                  carry zeros / identity filler into the average)
                  | "global" (FedADP-U: the server substitutes its own
                  current values for uncovered regions — uncovered
                  parameters are simply not pulled toward the filler)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedADP, ClusteredFL, FlexiFed, Standalone, vgg_chain
from repro.core.aggregation import client_weights, fedavg
from repro.data.federated import ClientSampler
from repro.optim import sgd


@dataclass
class FLRunConfig:
    method: str = "fedadp"
    rounds: int = 20
    local_epochs: int = 2
    lr: float = 0.01
    momentum: float = 0.0
    narrow_mode: str = "paper"
    filler: str = "zero"
    seed: int = 0
    eval_every: int = 1


class Simulator:
    def __init__(self, family, client_cfgs: Sequence, samplers: List[ClientSampler],
                 run_cfg: FLRunConfig, eval_batch: Dict[str, np.ndarray]):
        self.family = family
        self.client_cfgs = list(client_cfgs)
        self.samplers = samplers
        self.cfg = run_cfg
        self.eval_batch = eval_batch
        self.n_samples = [s.n_samples for s in samplers]
        self._grad_fns: Dict[str, Callable] = {}
        self._opt = sgd(run_cfg.lr, run_cfg.momentum)

    # ------------------------------------------------------------ pieces
    def _grad_fn(self, cfg):
        if cfg.name not in self._grad_fns:
            f = self.family.loss_and_grad(cfg)
            self._grad_fns[cfg.name] = jax.jit(f)
        return self._grad_fns[cfg.name]

    def _local_train(self, k: int, params):
        cfg = self.client_cfgs[k]
        gf = self._grad_fn(cfg)
        opt_state = self._opt.init(params)
        step = 0
        for batch in self.samplers[k].round_batches(self.cfg.local_epochs):
            (_, _), grads = gf(params, batch)
            params, opt_state = self._opt.update(grads, opt_state, params, step)
            step += 1
        return params

    def _evaluate_clients(self, client_params) -> float:
        accs = [self.family.evaluate(p, c, self.eval_batch)
                for p, c in zip(client_params, self.client_cfgs)]
        return float(np.mean(accs))

    # -------------------------------------------------------------- runs
    def run(self, key=None) -> Dict[str, Any]:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        method = self.cfg.method
        hist: List[float] = []
        t0 = time.time()

        if method == "fedadp":
            algo = FedADP(self.family, self.client_cfgs, self.n_samples,
                          narrow_mode=self.cfg.narrow_mode,
                          base_seed=self.cfg.seed)
            gparams = algo.init_global(key)
            for r in range(self.cfg.rounds):
                if self.cfg.filler == "global":
                    gparams = self._round_fedadp_globalfill(algo, gparams, r)
                else:
                    gparams = algo.round(gparams, self._local_train, r)
                if (r + 1) % self.cfg.eval_every == 0:
                    cps = [algo.distribute(gparams, r + 1, k)
                           for k in range(len(self.client_cfgs))]
                    hist.append(self._evaluate_clients(cps))
            final = [algo.distribute(gparams, self.cfg.rounds, k)
                     for k in range(len(self.client_cfgs))]
            return self._result(hist, final, t0, global_params=gparams)

        # per-client-parameter methods
        client_params = [self.family.init(jax.random.fold_in(key, k), c)
                         for k, c in enumerate(self.client_cfgs)]
        if method == "standalone":
            algo = Standalone(self.client_cfgs, self.n_samples)
        elif method == "clustered":
            algo = ClusteredFL(self.client_cfgs, self.n_samples)
        elif method == "flexifed":
            algo = FlexiFed(self.client_cfgs, self.n_samples, vgg_chain)
        else:
            raise ValueError(method)
        for r in range(self.cfg.rounds):
            client_params = algo.round(client_params, self._local_train, r)
            if (r + 1) % self.cfg.eval_every == 0:
                hist.append(self._evaluate_clients(client_params))
        return self._result(hist, client_params, t0)

    def _round_fedadp_globalfill(self, algo: FedADP, gparams, r: int):
        """FedADP-U: uncovered regions keep the server's values instead of
        the zero/identity filler (beyond-paper; see module docstring)."""
        expanded, masks = [], []
        for k in range(len(self.client_cfgs)):
            ck = algo.distribute(gparams, r, k)
            ck = self._local_train(k, ck)
            up_k = algo.collect(ck, r, k)
            ones = jax.tree.map(jnp.ones_like, ck)
            mask = jax.tree.map(lambda m: (jnp.abs(m) > 0).astype(jnp.float32),
                                algo.collect(ones, r, k))
            filled = jax.tree.map(lambda u, m, g: u * m + g * (1 - m),
                                  up_k, mask, gparams)
            expanded.append(filled)
        w = algo.weights / algo.weights.sum()
        return fedavg(expanded, w)

    def _result(self, hist, client_params, t0, global_params=None):
        return {"history": hist,
                "final_acc": hist[-1] if hist else None,
                "client_params": client_params,
                "global_params": global_params,
                "wall_s": time.time() - t0}
