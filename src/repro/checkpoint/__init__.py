from repro.checkpoint.store import (  # noqa: F401
    load_plane, load_pytree, save_plane, save_pytree)
