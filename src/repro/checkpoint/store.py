"""Pytree checkpointing: npz payload + structure manifest.

Path-keyed (stable across pytree registration details), dtype-preserving,
and atomic (write temp + rename). Sufficient for single-host jobs and the
FL server state; a production multi-host deployment would swap in a
sharded array-io backend behind the same two calls.

``save_plane``/``load_plane`` persist a packed parameter plane
(``core.plane``) as ONE contiguous array plus its ``PlaneSpec`` layout in
the manifest — bit-exact resume (the plane is f32; the spec records each
leaf's storage dtype so ``unpack`` restores the original tree), with the
same temp+rename atomicity.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np


_VIEW = {2: np.uint16, 1: np.uint8}  # ml_dtypes (bf16/fp8) -> raw view


def _to_native(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind in "fiub" and arr.dtype.str.lstrip("<>|=") in (
            "f2", "f4", "f8", "i1", "i2", "i4", "i8", "u1", "u2", "u4",
            "u8", "b1"):
        return arr
    return arr.view(_VIEW[arr.dtype.itemsize])


def _from_native(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    try:
        want = np.dtype(dtype_str)
    except TypeError:
        import ml_dtypes
        want = np.dtype(getattr(ml_dtypes, dtype_str))
    if arr.dtype == want:
        return arr
    if arr.dtype.itemsize == want.itemsize and arr.dtype.kind == "u":
        return arr.view(want)
    return arr.astype(want)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree, *, extra: Dict[str, Any] | None = None):
    flat = _flatten(tree)
    manifest = {
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "extra": extra or {},
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".npz")
    os.close(fd)
    np.savez(tmp, __manifest__=json.dumps(manifest),
             **{k.replace("/", "§"): _to_native(v) for k, v in flat.items()})
    os.replace(tmp, path)


def save_plane(path: str, plane, spec, *, extra: Dict[str, Any] | None = None):
    """Persist a packed ``(P,)`` or ``(K, P)`` plane + its ``PlaneSpec``:
    one payload array, the layout (paths/shapes/dtypes) in the JSON
    manifest. Round-trips bit-exactly (``load_plane``)."""
    arr = np.asarray(plane)
    manifest = {
        "plane": {"dtype": str(arr.dtype), "shape": list(arr.shape),
                  "spec": spec.to_manifest()},
        "extra": extra or {},
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".npz")
    os.close(fd)
    np.savez(tmp, __manifest__=json.dumps(manifest),
             __plane__=_to_native(arr))
    os.replace(tmp, path)


def load_plane(path: str):
    """Load a plane checkpoint -> ``(plane, PlaneSpec, extra)``. The
    returned array is bit-identical to what ``save_plane`` was given;
    ``core.plane.unpack`` with the returned spec restores the tree."""
    from repro.core.plane import PlaneSpec
    data = np.load(path, allow_pickle=False)
    manifest = json.loads(str(data["__manifest__"]))
    meta = manifest["plane"]
    arr = _from_native(data["__plane__"], meta["dtype"])
    assert list(arr.shape) == meta["shape"], (arr.shape, meta["shape"])
    return arr, PlaneSpec.from_manifest(meta["spec"]), manifest["extra"]


def load_pytree(path: str, like=None):
    """Load a checkpoint. If ``like`` (a template pytree) is given, values
    are arranged into its structure; otherwise a nested dict is returned."""
    data = np.load(path, allow_pickle=False)
    manifest = json.loads(str(data["__manifest__"]))
    flat = {k: _from_native(data[k.replace("/", "§")],
                            manifest["dtypes"][k])
            for k in manifest["keys"]}
    if like is None:
        nested: Dict[str, Any] = {}
        for k, v in flat.items():
            cur = nested
            parts = k.split("/")
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = v
        return nested, manifest["extra"]

    leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
    out = []
    for path_, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return (jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), manifest["extra"])
