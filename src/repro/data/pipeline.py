"""Batch pipeline for LM training: deterministic, shardable, host-side.

Produces global batches (numpy) that the launcher feeds to ``jit`` with
data-parallel sharding; in a real multi-host job each host would emit its
slice (same interface — ``host_slice``).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.data.synthetic import lm_sequences


class LMPipeline:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int, *,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        seqs = lm_sequences(self.vocab_size, self.global_batch, self.seq_len,
                            seed=self.seed * 100_003 + self._step)
        self._step += 1
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}

    def host_slice(self, batch: Dict[str, np.ndarray], host_id: int,
                   n_hosts: int) -> Dict[str, np.ndarray]:
        b = self.global_batch // n_hosts
        return {k: v[host_id * b:(host_id + 1) * b] for k, v in batch.items()}
