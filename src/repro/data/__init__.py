from repro.data.federated import (  # noqa: F401
    ClientSampler, dirichlet_partition, iid_partition)
from repro.data.pipeline import LMPipeline  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    EASY, HARD, HARDEST, MEDIUM, TABLE1_TASKS, ImageTaskSpec,
    image_classification, lm_sequences)
