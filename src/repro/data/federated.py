"""Federated partitioning: split a dataset across K clients.

``dirichlet_partition`` is the standard non-IID label-skew protocol
(Dir(alpha) over class proportions per client). ``iid_partition`` matches
the paper's main setting (it reports no explicit skew protocol; clients
draw 20% of their local data per round — see ``ClientSampler``).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(n: int, k: int, *, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(part) for part in np.array_split(idx, k)]


def dirichlet_partition(labels: np.ndarray, k: int, *, alpha: float = 0.5,
                        seed: int = 0, min_size: int = 8) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        parts: List[List[int]] = [[] for _ in range(k)]
        for c in range(n_classes):
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * k)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx, cuts)):
                parts[i].extend(part.tolist())
        if min(len(p) for p in parts) >= min_size:
            return [np.sort(np.asarray(p)) for p in parts]


class ClientSampler:
    """Per-round local batch stream. The paper: 'Clients will use 20% of
    their datasets in each round of training', local epochs E over it."""

    def __init__(self, data: Dict[str, np.ndarray], indices: np.ndarray, *,
                 round_fraction: float = 0.2, batch_size: int = 64,
                 seed: int = 0):
        self.data = data
        self.indices = np.asarray(indices)
        self.round_fraction = round_fraction
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)

    @property
    def n_samples(self) -> int:
        return len(self.indices)

    def round_batches(self, epochs: int = 1):
        take = max(self.batch_size,
                   int(len(self.indices) * self.round_fraction))
        sel = self.rng.choice(self.indices, size=min(take, len(self.indices)),
                              replace=False)
        for _ in range(epochs):
            order = self.rng.permutation(len(sel))
            for i in range(0, len(sel), self.batch_size):
                batch_idx = sel[order[i:i + self.batch_size]]
                if len(batch_idx) < 2:
                    continue
                yield {k: v[batch_idx] for k, v in self.data.items()}
