"""Federated partitioning: split a dataset across K clients.

``dirichlet_partition`` is the standard non-IID label-skew protocol
(Dir(alpha) over class proportions per client). ``iid_partition`` matches
the paper's main setting (it reports no explicit skew protocol; clients
draw 20% of their local data per round — see ``ClientSampler``).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(n: int, k: int, *, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(part) for part in np.array_split(idx, k)]


def dirichlet_partition(labels: np.ndarray, k: int, *, alpha: float = 0.5,
                        seed: int = 0, min_size: int = 8,
                        max_retries: int = 1000) -> List[np.ndarray]:
    """Rejection-sample Dir(alpha) splits until every client holds at
    least ``min_size`` samples. Infeasible settings (e.g. ``k * min_size``
    close to or above ``len(labels)``, or a tiny ``alpha`` that
    concentrates whole classes on single clients) fail fast with a
    ``ValueError`` after ``max_retries`` draws instead of looping
    forever."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(max_retries):
        parts: List[List[int]] = [[] for _ in range(k)]
        for c in range(n_classes):
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * k)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx, cuts)):
                parts[i].extend(part.tolist())
        if min(len(p) for p in parts) >= min_size:
            return [np.sort(np.asarray(p)) for p in parts]
    raise ValueError(
        f"dirichlet_partition: no draw satisfied min_size={min_size} after "
        f"{max_retries} retries (n={len(labels)}, k={k}, alpha={alpha}); "
        "lower min_size or k, or raise alpha")


class ClientSampler:
    """Per-round local batch stream. The paper: 'Clients will use 20% of
    their datasets in each round of training', local epochs E over it.

    Tail handling: a trailing batch smaller than ``min_batch`` is MERGED
    into the previous batch (the last batch can grow up to
    ``batch_size + min_batch - 1``), so no drawn sample is silently
    dropped and a client with any data contributes at least one step per
    round — previously a <2-sample tail was discarded, which could leave
    a client at zero steps. When the whole per-round draw is smaller
    than ``min_batch`` it is yielded as-is (there is nothing to merge
    into)."""

    def __init__(self, data: Dict[str, np.ndarray], indices: np.ndarray, *,
                 round_fraction: float = 0.2, batch_size: int = 64,
                 seed: int = 0, min_batch: int = 2):
        self.data = data
        self.indices = np.asarray(indices)
        self.round_fraction = round_fraction
        self.batch_size = batch_size
        self.min_batch = min_batch
        self.rng = np.random.default_rng(seed)

    @property
    def n_samples(self) -> int:
        return len(self.indices)

    def _round_take(self) -> int:
        return min(max(self.batch_size,
                       int(len(self.indices) * self.round_fraction)),
                   len(self.indices))

    def _batch_starts(self, take: int):
        """Start offsets of one epoch's batches over a ``take``-sample
        draw — the single definition both ``round_batches`` and
        ``steps_per_epoch`` read, so they cannot desynchronize."""
        starts = list(range(0, take, self.batch_size))
        if len(starts) > 1 and take - starts[-1] < self.min_batch:
            starts.pop()               # merge the short tail into the
                                       # previous batch
        return starts

    def steps_per_epoch(self) -> int:
        """Exact number of batches one epoch of ``round_batches`` yields."""
        return len(self._batch_starts(self._round_take()))

    def round_batches(self, epochs: int = 1):
        sel = self.rng.choice(self.indices, size=self._round_take(),
                              replace=False)
        starts = self._batch_starts(len(sel))
        for _ in range(epochs):
            order = self.rng.permutation(len(sel))
            for j, i in enumerate(starts):
                end = starts[j + 1] if j + 1 < len(starts) else len(sel)
                batch_idx = sel[order[i:end]]
                yield {k: v[batch_idx] for k, v in self.data.items()}
