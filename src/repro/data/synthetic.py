"""Synthetic datasets (offline gate — repro band 2/5: MNIST/CIFAR are not
downloadable in this container; DESIGN.md §2).

Two generators:

  * ``image_classification`` — a frozen random convnet "teacher" labels
    latent-structured images. Difficulty is controlled by the number of
    classes and label noise, giving MNIST-like ("easy") and CIFAR-like
    ("hard") proxies for the Table-1 experiments. Collaboration helps
    because every client's data comes from the same teacher.
  * ``lm_sequences`` — Zipf-distributed token streams from a random
    order-1 Markov source (shared transition structure), for LM training
    of the transformer families.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class ImageTaskSpec:
    name: str
    n_classes: int
    image_size: int = 32
    channels: int = 3
    latent_dim: int = 24
    label_noise: float = 0.0
    seed: int = 0


EASY = ImageTaskSpec("synth-easy", n_classes=10, label_noise=0.0, seed=11)
MEDIUM = ImageTaskSpec("synth-medium", n_classes=10, label_noise=0.15, seed=12)
HARD = ImageTaskSpec("synth-hard", n_classes=20, label_noise=0.1,
                     latent_dim=48, seed=13)
HARDEST = ImageTaskSpec("synth-hardest", n_classes=50, label_noise=0.15,
                        latent_dim=64, seed=14)

TABLE1_TASKS = (EASY, MEDIUM, HARD, HARDEST)  # MNIST/F-MNIST/CIFAR-10/100 proxies


def _teacher_logits(rng: np.random.Generator, z: np.ndarray, n_classes: int):
    """Frozen 2-layer MLP teacher on the latent code."""
    d = z.shape[1]
    w1 = rng.standard_normal((d, 64)) / np.sqrt(d)
    w2 = rng.standard_normal((64, n_classes)) / np.sqrt(64)
    return np.maximum(z @ w1, 0.0) @ w2


def image_classification(spec: ImageTaskSpec, n: int, *, seed: int = 0
                         ) -> Dict[str, np.ndarray]:
    """Returns {'x': (n, S, S, C) float32, 'y': (n,) int32}."""
    rng_task = np.random.default_rng(spec.seed)          # frozen task params
    rng = np.random.default_rng((spec.seed + 1) * 77 + seed)
    z = rng.standard_normal((n, spec.latent_dim)).astype(np.float32)
    logits = _teacher_logits(rng_task, z, spec.n_classes)
    y = logits.argmax(-1).astype(np.int32)
    # render latents into images via a frozen linear decoder + nonlinearity
    dec = rng_task.standard_normal(
        (spec.latent_dim, spec.image_size * spec.image_size * spec.channels)
    ).astype(np.float32) / np.sqrt(spec.latent_dim)
    x = np.tanh(z @ dec).reshape(n, spec.image_size, spec.image_size,
                                 spec.channels)
    x = x + 0.05 * rng.standard_normal(x.shape).astype(np.float32)
    if spec.label_noise > 0:
        flip = rng.random(n) < spec.label_noise
        y = np.where(flip, rng.integers(0, spec.n_classes, n), y).astype(np.int32)
    return {"x": x.astype(np.float32), "y": y}


def lm_sequences(vocab_size: int, n_seqs: int, seq_len: int, *,
                 seed: int = 0, order: int = 1) -> np.ndarray:
    """Zipf-weighted Markov token streams -> (n_seqs, seq_len+1) int32.

    The +1 column lets callers split into (inputs, next-token labels).
    """
    rng_task = np.random.default_rng(1234)
    rng = np.random.default_rng(seed)
    V = vocab_size
    branch = 32                                           # sparse transitions
    succ = rng_task.integers(0, V, size=(V, branch))
    zipf = 1.0 / (np.arange(1, branch + 1) ** 1.2)
    zipf = zipf / zipf.sum()
    out = np.empty((n_seqs, seq_len + 1), np.int32)
    state = rng.integers(0, V, size=n_seqs)
    for t in range(seq_len + 1):
        out[:, t] = state
        choice = rng.choice(branch, size=n_seqs, p=zipf)
        state = succ[state, choice]
    return out
